//! Persistent scoped worker pool for shard-parallel simulation phases.
//!
//! The offline image ships no rayon/crossbeam, and `std::thread::scope`
//! alone would respawn OS threads every simulated cycle — far too slow
//! for a hot loop that fans out small shard jobs millions of times. So
//! this is the classic *scoped threadpool* shape built on std only:
//! workers are spawned once and live as long as the pool; a
//! [`WorkerPool::scoped`] call opens a region in which borrowed
//! (non-`'static`) jobs may be submitted, and it does not return until
//! every submitted job has finished, which is what makes handing the
//! workers `&mut` shard views of caller-owned arenas sound.
//!
//! Determinism: the pool makes **no** ordering promises — jobs run on
//! whatever worker grabs them first. Callers get determinism the way the
//! NoC simulator does (see `noc/sim.rs` module docs): jobs touch only
//! disjoint state and emit cross-shard side effects into per-job scratch
//! buffers that the caller merges sequentially in a fixed order after
//! `scoped` returns.
//!
//! # Wake path: spin-then-park
//!
//! The fan-out pattern above submits a handful of sub-microsecond shard
//! jobs every simulated cycle. Parking each idle worker on the condvar
//! between cycles would put one futex round-trip *per worker per cycle*
//! on the critical path — the dominant Amdahl tail of the parallel NoC
//! step at small shard sizes. Instead, idle workers spin on a lock-free
//! *wake generation* counter ([`Shared::gen`]) with bounded backoff
//! (busy polls, then `yield_now` polls) and only fall back to a condvar
//! park once the budget is exhausted — so back-to-back `scoped` regions
//! hand off work without any syscall, while an idle pool still goes
//! fully to sleep. Submitters bump the generation *and* notify the
//! condvar; parking re-checks the queue under the lock after recording
//! the generation, so a wake between "queue empty" and "wait" cannot be
//! lost. This changes scheduling latency only — job semantics, the
//! completion barrier and the panic contract below are untouched.
//!
//! # Panic contract
//!
//! A panicking job must not take the pool down with it — a wedged or
//! poisoned pool would turn one bad shard into a hang of every later
//! simulation phase. The contract:
//!
//! * Workers catch job unwinds ([`std::panic::catch_unwind`]), so a
//!   panicking job still decrements the pending count and the
//!   completion barrier **always** releases — no deadlock, ever.
//! * The panic is re-raised from the *same* [`WorkerPool::scoped`] call
//!   (message `"WorkerPool: a scoped job panicked"`), after every
//!   submitted job of the region has finished. Multiple panicking jobs
//!   fold into that one re-raise.
//! * The pool survives: workers stay alive (the unwind never crosses
//!   the worker loop), the panic flag is cleared at the start of every
//!   region, and later `scoped` regions run unaffected — including the
//!   case where the *closure* unwound (from the re-entrant panic of a
//!   nested region or its own bug) and the flag would otherwise leak.
//! * Side effects of jobs that completed before/alongside the panicking
//!   one are retained (they ran to completion behind the barrier);
//!   the panicking job's partial writes are whatever it made them —
//!   callers treat a panicked region's output as garbage and must not
//!   merge it.

use std::collections::VecDeque;
use std::marker::PhantomData;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Busy (`spin_loop`) polls of the wake generation before an idle worker
/// starts yielding its timeslice, and additional `yield_now` polls after
/// that before it parks on the condvar. The budget only has to cover the
/// caller's inter-region gap (merge + next epoch's sequential phase),
/// which is short precisely when parallelism matters — measured shapes
/// park within ~10us of going idle.
const SPIN_POLLS: u32 = 128;
const YIELD_POLLS: u32 = 32;

/// A queued unit of work. Jobs are type-erased closures; the `'static`
/// bound is a lie told once, in [`Scope::execute`], and made true by
/// [`WorkerPool::scoped`]'s completion barrier.
type Job = Box<dyn FnOnce() + Send + 'static>;

#[derive(Default)]
struct State {
    queue: VecDeque<Job>,
    /// Jobs submitted but not yet finished (queued + running).
    pending: usize,
    /// Set when any job panicked; re-raised by `scoped`.
    panicked: bool,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Wake generation: bumped (Release) on every submit and on
    /// shutdown. Idle workers spin on it (Acquire) out of the lock
    /// before parking — see the module docs' wake-path section.
    gen: AtomicUsize,
    /// Parks workers past the spin budget: work available or shutdown.
    work: Condvar,
    /// Wakes the scope owner: `pending` reached zero.
    done: Condvar,
}

/// A fixed-size pool of persistent worker threads executing borrowed
/// jobs inside [`WorkerPool::scoped`] regions.
pub struct WorkerPool {
    shared: Arc<Shared>,
    threads: Vec<JoinHandle<()>>,
}

/// Handle for submitting jobs inside a [`WorkerPool::scoped`] region.
/// The `'scope` lifetime is invariant (same trick as `std::thread::Scope`)
/// so submitted jobs may borrow anything that outlives the region.
pub struct Scope<'pool, 'scope> {
    pool: &'pool WorkerPool,
    _scope: PhantomData<std::cell::Cell<&'scope mut ()>>,
}

impl WorkerPool {
    /// Spawn `workers` persistent threads (>= 1).
    pub fn new(workers: usize) -> Self {
        assert!(workers >= 1, "a worker pool needs at least one thread");
        let shared = Arc::new(Shared {
            state: Mutex::new(State::default()),
            gen: AtomicUsize::new(0),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let threads = (0..workers)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("sim-worker-{i}"))
                    .spawn(move || worker_loop(&sh))
                    .expect("spawning simulation worker thread")
            })
            .collect();
        WorkerPool { shared, threads }
    }

    pub fn workers(&self) -> usize {
        self.threads.len()
    }

    /// Run `f` with a [`Scope`] that accepts borrowed jobs, then block
    /// until every submitted job has completed. Blocks-before-returning
    /// is the soundness contract: no job can outlive the borrows it
    /// captured. If `f` itself unwinds, the guard still waits for the
    /// already-submitted jobs before the panic propagates. A panic
    /// inside a job is re-raised here after all jobs finish.
    pub fn scoped<'pool, 'scope, R>(
        &'pool mut self,
        f: impl FnOnce(&Scope<'pool, 'scope>) -> R,
    ) -> R {
        struct WaitGuard<'a>(&'a WorkerPool);
        impl Drop for WaitGuard<'_> {
            fn drop(&mut self) {
                let st = self.0.shared.state.lock().unwrap();
                drop(self.0.shared.done.wait_while(st, |s| s.pending != 0).unwrap());
            }
        }
        // Start the region with a clean panic flag: if a previous
        // region's *closure* unwound after one of its jobs panicked, the
        // take below never ran and the flag would otherwise leak into
        // this region and fail it spuriously.
        self.shared.state.lock().unwrap().panicked = false;
        let scope = Scope { pool: self, _scope: PhantomData };
        let guard = WaitGuard(self);
        let out = f(&scope);
        drop(guard); // completion barrier
        let panicked = {
            let mut st = self.shared.state.lock().unwrap();
            std::mem::take(&mut st.panicked)
        };
        if panicked {
            panic!("WorkerPool: a scoped job panicked");
        }
        out
    }
}

impl<'scope> Scope<'_, 'scope> {
    /// Submit a job that may borrow state alive for `'scope`.
    pub fn execute(&self, f: impl FnOnce() + Send + 'scope) {
        let job: Box<dyn FnOnce() + Send + 'scope> = Box::new(f);
        // SAFETY: the job is only reachable by pool workers, and
        // `WorkerPool::scoped` does not return (even on unwind — see
        // WaitGuard) until `pending == 0`, i.e. until this job has run
        // to completion and been dropped. Every `'scope` borrow the
        // closure captured therefore strictly outlives the closure's
        // actual lifetime, and erasing the lifetime to `'static` is
        // unobservable. This is the `scoped_threadpool` construction.
        let job: Job = unsafe {
            std::mem::transmute::<Box<dyn FnOnce() + Send + 'scope>, Job>(job)
        };
        let mut st = self.pool.shared.state.lock().unwrap();
        st.pending += 1;
        st.queue.push_back(job);
        drop(st);
        // Wake spinners (generation bump) and at most one parked worker.
        // Order doesn't matter for correctness: parking re-checks the
        // queue under the lock, and spinners re-lock before popping.
        self.pool.shared.gen.fetch_add(1, Ordering::Release);
        self.pool.shared.work.notify_one();
    }
}

/// Contiguous shard fences `[0, f1, .., n]` over `weights`, splitting
/// `weights.len()` items into at most `shards` non-empty ranges of
/// roughly equal mass. This is the *load-aware* partitioner behind the
/// shard-parallel engines (ROADMAP follow-up (l)): fences cut by
/// accumulated weight instead of uniform item count, so one hot resource
/// (an HBM queue, a busy DSE candidate group) no longer serializes its
/// shard while the others idle.
///
/// Properties callers rely on:
/// * fences are strictly increasing (every shard is non-empty), start at
///   0 and end at `weights.len()`;
/// * exactly `min(shards, weights.len())` ranges are produced — the same
///   shard count the old uniform split gave, so thread fan-out never
///   shrinks under a skewed history;
/// * each weight is padded by +1 mass, so an all-zero history degrades
///   to the old uniform count split instead of one giant shard;
/// * the cut is greedy left-to-right: a range closes once its mass
///   reaches its fair share of the mass still unassigned, or when the
///   items left are exactly enough to give each remaining range one.
///
/// Determinism note: the *choice* of fences never affects simulation
/// results — the shard contract guarantees bit-identical output for
/// every valid partition (pinned by the partition-invariance property
/// tests) — so callers may feed approximate, even stale, weights.
pub fn load_fences(weights: &[u64], shards: usize) -> Vec<usize> {
    let n = weights.len();
    assert!(n > 0, "load_fences needs at least one item");
    let shards = shards.clamp(1, n);
    // u128 accumulators: n * (u64::MAX + 1) cannot overflow.
    let mut rem: u128 = weights.iter().map(|&w| w as u128 + 1).sum();
    let mut fences = Vec::with_capacity(shards + 1);
    fences.push(0usize);
    let mut acc: u128 = 0;
    for (i, &w) in weights.iter().enumerate() {
        let m = w as u128 + 1;
        acc += m;
        rem -= m;
        let closed = fences.len() - 1;
        // Ranges still to emit, counting the one currently open.
        let open = (shards - closed) as u128;
        let items_left = n - (i + 1);
        let ranges_left = shards - closed - 1;
        if closed + 1 < shards
            && items_left >= ranges_left
            && (acc * open >= acc + rem || items_left == ranges_left)
        {
            fences.push(i + 1);
            acc = 0;
        }
    }
    fences.push(n);
    debug_assert!(fences.windows(2).all(|w| w[0] < w[1]));
    fences
}

fn worker_loop(sh: &Shared) {
    loop {
        // Spin-then-park gate (module docs): the lock is taken only to
        // grab work or to park; waiting happens on `gen` out of the lock.
        let job = {
            let mut polls = 0u32;
            let mut seen = sh.gen.load(Ordering::Acquire);
            'grab: loop {
                {
                    let mut st = sh.state.lock().unwrap();
                    if let Some(j) = st.queue.pop_front() {
                        break 'grab j;
                    }
                    if st.shutdown {
                        return;
                    }
                    if polls >= SPIN_POLLS + YIELD_POLLS {
                        // Park. The queue was re-checked under this
                        // lock just above, so a submit's bump+notify
                        // cannot slip between the check and the wait.
                        let st = sh.work.wait(st).unwrap();
                        drop(st);
                        polls = 0;
                        seen = sh.gen.load(Ordering::Acquire);
                        continue 'grab;
                    }
                }
                // Out of the lock: poll the wake generation with
                // bounded backoff until it moves (or the budget runs
                // out, in which case the next lap parks).
                loop {
                    let g = sh.gen.load(Ordering::Acquire);
                    if g != seen {
                        seen = g;
                        break;
                    }
                    polls += 1;
                    if polls >= SPIN_POLLS + YIELD_POLLS {
                        break;
                    }
                    if polls >= SPIN_POLLS {
                        std::thread::yield_now();
                    } else {
                        std::hint::spin_loop();
                    }
                }
            }
        };
        // Catch unwinds so one bad job cannot wedge the completion
        // barrier; `scoped` re-raises after the barrier.
        let ok = catch_unwind(AssertUnwindSafe(job)).is_ok();
        let mut st = sh.state.lock().unwrap();
        st.pending -= 1;
        if !ok {
            st.panicked = true;
        }
        if st.pending == 0 {
            sh.done.notify_all();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.gen.fetch_add(1, Ordering::Release);
        self.shared.work.notify_all();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_borrowed_jobs_to_completion() {
        let mut pool = WorkerPool::new(3);
        let mut data = vec![0u64; 64];
        let chunks: Vec<&mut [u64]> = data.chunks_mut(16).collect();
        pool.scoped(|scope| {
            for (i, chunk) in chunks.into_iter().enumerate() {
                scope.execute(move || {
                    for (j, v) in chunk.iter_mut().enumerate() {
                        *v = (i * 16 + j) as u64;
                    }
                });
            }
        });
        assert!(data.iter().enumerate().all(|(i, &v)| v == i as u64));
    }

    #[test]
    fn scope_is_a_completion_barrier() {
        let mut pool = WorkerPool::new(2);
        let counter = AtomicUsize::new(0);
        for round in 1..=5 {
            pool.scoped(|scope| {
                for _ in 0..8 {
                    scope.execute(|| {
                        counter.fetch_add(1, Ordering::SeqCst);
                    });
                }
            });
            // All 8 jobs of this round observed before the next round.
            assert_eq!(counter.load(Ordering::SeqCst), round * 8);
        }
    }

    #[test]
    fn pool_outlives_many_scopes() {
        let mut pool = WorkerPool::new(2);
        let mut total = 0u64;
        for i in 0..100u64 {
            let mut parts = [0u64; 4];
            pool.scoped(|scope| {
                let mut rest = &mut parts[..];
                for k in 0..4u64 {
                    let (head, tail) = std::mem::take(&mut rest).split_at_mut(1);
                    rest = tail;
                    scope.execute(move || head[0] = i + k);
                }
            });
            total += parts.iter().sum::<u64>();
        }
        assert_eq!(total, (0..100u64).map(|i| 4 * i + 6).sum::<u64>());
    }

    #[test]
    fn job_panic_propagates_after_barrier() {
        let mut pool = WorkerPool::new(2);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.scoped(|scope| {
                scope.execute(|| panic!("job boom"));
            });
        }));
        assert!(r.is_err(), "panic must propagate out of scoped");
        // Pool still usable afterwards.
        let mut x = 0u32;
        pool.scoped(|scope| scope.execute(|| x = 7));
        assert_eq!(x, 7);
    }

    #[test]
    fn many_panicking_jobs_fold_into_one_reraise_and_surviving_work_lands() {
        let mut pool = WorkerPool::new(3);
        let counter = AtomicUsize::new(0);
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.scoped(|scope| {
                for i in 0..16 {
                    scope.execute(|| {
                        if i % 4 == 0 {
                            panic!("shard {i} boom");
                        }
                        counter.fetch_add(1, Ordering::SeqCst);
                    });
                }
            });
        }));
        assert!(r.is_err(), "at least one job panic must surface");
        // The barrier ran every job: the 12 healthy shards all landed
        // even though 4 of their siblings panicked.
        assert_eq!(counter.load(Ordering::SeqCst), 12);
    }

    /// The spin budget is tiny compared to a millisecond sleep, so every
    /// round below finds all workers parked on the condvar — the
    /// park-and-rewake path of the spin-then-park gate must deliver the
    /// jobs, not just the warm spinning path the other tests exercise.
    #[test]
    fn parked_workers_wake_after_idle_gaps() {
        let mut pool = WorkerPool::new(2);
        let counter = AtomicUsize::new(0);
        for round in 1..=3 {
            std::thread::sleep(std::time::Duration::from_millis(25));
            pool.scoped(|scope| {
                for _ in 0..4 {
                    scope.execute(|| {
                        counter.fetch_add(1, Ordering::SeqCst);
                    });
                }
            });
            assert_eq!(counter.load(Ordering::SeqCst), round * 4);
        }
    }

    #[test]
    fn load_fences_uniform_when_history_is_cold() {
        // All-zero weights (+1 padding) reduce to the old count split.
        assert_eq!(load_fences(&[0; 8], 4), vec![0, 2, 4, 6, 8]);
        assert_eq!(load_fences(&[0; 5], 2), vec![0, 3, 5]); // ceil-ish halves
        assert_eq!(load_fences(&[0; 3], 1), vec![0, 3]);
        // More shards than items: every item its own range, no empties.
        assert_eq!(load_fences(&[0; 2], 8), vec![0, 1, 2]);
    }

    #[test]
    fn load_fences_isolate_a_hot_item() {
        // One item carries ~all the mass: it gets its own shard and the
        // cold tail is shared out instead of serializing behind it.
        let mut w = vec![0u64; 8];
        w[0] = 1_000_000;
        let f = load_fences(&w, 4);
        assert_eq!(f[0], 0);
        assert_eq!(f[1], 1, "hot head must be cut off immediately: {f:?}");
        assert_eq!(*f.last().unwrap(), 8);
        assert!(f.windows(2).all(|p| p[0] < p[1]), "non-empty shards: {f:?}");
    }

    #[test]
    fn load_fences_are_always_a_valid_partition() {
        // Adversarial shapes: hot tail, alternating, huge weights.
        let cases: Vec<(Vec<u64>, usize)> = vec![
            ((0..16).map(|i| if i == 15 { u64::MAX } else { 0 }).collect(), 4),
            ((0..9).map(|i| (i % 2) * 1000).collect(), 3),
            (vec![u64::MAX; 4], 4),
            (vec![7], 5),
        ];
        for (w, shards) in cases {
            let f = load_fences(&w, shards);
            assert_eq!(f[0], 0);
            assert_eq!(*f.last().unwrap(), w.len());
            assert_eq!(f.len() - 1, shards.min(w.len()), "{f:?} vs {shards} shards");
            assert!(f.windows(2).all(|p| p[0] < p[1]), "{f:?}");
        }
    }

    #[test]
    fn pool_is_not_corrupted_by_repeated_panics() {
        let mut pool = WorkerPool::new(2);
        for round in 0..5u64 {
            let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
                pool.scoped(|scope| {
                    scope.execute(move || panic!("round {round}"));
                });
            }));
            assert!(r.is_err());
            // A clean region immediately after each panic: the flag was
            // reset, all workers are alive, the barrier still holds.
            let mut parts = [0u64; 2];
            pool.scoped(|scope| {
                let (a, b) = parts.split_at_mut(1);
                scope.execute(move || a[0] = round + 1);
                scope.execute(move || b[0] = round + 2);
            });
            assert_eq!(parts, [round + 1, round + 2]);
        }
        assert_eq!(pool.workers(), 2, "no worker thread died");
    }
}
