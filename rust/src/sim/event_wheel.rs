//! Bucketed event wheel (calendar queue) for bounded-delay event storage.
//!
//! The heap-based [`super::EventQueue`] is the general-purpose scheduler:
//! O(log n) per operation, arbitrary horizons. The hot simulation loops
//! (NoC flit arrivals and credit returns, DRAM wakeups) have a different
//! profile: every cycle schedules many events a *small, bounded* number of
//! cycles into the future, and every cycle drains everything due. For
//! that shape a calendar queue is O(1) per push and O(due) per drain with
//! no per-event allocation:
//!
//! * `slots` is a power-of-two ring of buckets; an event at absolute
//!   cycle `t` lives in bucket `t & mask`.
//! * Pushing appends to the bucket, so events scheduled for the same
//!   cycle pop in scheduling order — the FIFO tie-break every
//!   determinism test relies on (same contract as `EventQueue`).
//! * [`EventWheel::take_due`] hands the caller the bucket's backing `Vec`
//!   (zero copy in the common case); [`EventWheel::recycle`] returns the
//!   storage so steady-state stepping performs no allocation at all.
//! * Events beyond the horizon simply land in a bucket a lap early; each
//!   entry carries its absolute cycle and `take_due` retains entries for
//!   later laps. Laps cost one compare per co-resident event and are
//!   impossible when the horizon covers the maximum delay (the NoC sizes
//!   its wheel from `router_latency`, so its fast path never laps).
//!
//! The FIFO-per-cycle guarantee is what the shard-parallel engines lean
//! on: a due bucket taken whole is a deterministic work list whose order
//! is independent of thread count, so both the NoC's sharded delivery
//! and the admission drain's epoch batches (via [`super::Calendar`])
//! fan out over `take_due` results and merge back without reordering.

use super::Cycle;

/// A bucketed calendar queue over absolute cycle timestamps.
#[derive(Debug)]
pub struct EventWheel<T> {
    /// Power-of-two ring of buckets; each entry keeps its absolute cycle.
    slots: Vec<Vec<(Cycle, T)>>,
    mask: u64,
    /// Total queued events across all buckets.
    count: usize,
    /// Recycled bucket storage (see [`EventWheel::recycle`]).
    free: Vec<Vec<(Cycle, T)>>,
}

impl<T> EventWheel<T> {
    /// Build a wheel whose ring covers at least `min_horizon` cycles
    /// (rounded up to a power of two, minimum 2). Events scheduled
    /// further out than the horizon are still correct — they wait in
    /// their bucket across laps — but a horizon covering the maximum
    /// delay keeps `take_due` on the swap fast path.
    pub fn with_horizon(min_horizon: usize) -> Self {
        let n = min_horizon.max(2).next_power_of_two();
        EventWheel {
            slots: (0..n).map(|_| Vec::new()).collect(),
            mask: (n - 1) as u64,
            count: 0,
            free: Vec::new(),
        }
    }

    /// Number of buckets in the ring.
    pub fn horizon(&self) -> usize {
        self.slots.len()
    }

    /// Schedule `item` at absolute cycle `at`. O(1) amortized.
    #[inline]
    pub fn push(&mut self, at: Cycle, item: T) {
        let s = (at & self.mask) as usize;
        self.slots[s].push((at, item));
        self.count += 1;
    }

    /// Batched ordered push: append every event in iteration order.
    /// Exactly equivalent to calling [`EventWheel::push`] in a loop —
    /// same-cycle events keep their iteration order for the FIFO
    /// tie-break. This is the shard-merge primitive: the parallel NoC
    /// step drains each shard's scratch buffer through here in global
    /// node order, replaying the sequential push sequence bit-for-bit.
    #[inline]
    pub fn push_all(&mut self, events: impl IntoIterator<Item = (Cycle, T)>) {
        for (at, item) in events {
            self.push(at, item);
        }
    }

    /// Remove and return every event scheduled exactly at `at`, in the
    /// order it was pushed. Events sharing the bucket but due on a later
    /// lap are retained. The returned `Vec` is backing storage on loan —
    /// hand it back via [`EventWheel::recycle`] to keep stepping
    /// allocation-free.
    pub fn take_due(&mut self, at: Cycle) -> Vec<(Cycle, T)> {
        let s = (at & self.mask) as usize;
        let mut due = self.free.pop().unwrap_or_default();
        debug_assert!(due.is_empty());
        if self.slots[s].iter().all(|&(t, _)| t == at) {
            // Fast path (also taken for an empty bucket): the whole
            // bucket is due — swap it out wholesale.
            std::mem::swap(&mut self.slots[s], &mut due);
        } else {
            // Lap collision: partition, preserving order of the
            // retained later-lap entries.
            let mut keep = self.free.pop().unwrap_or_default();
            for ev in self.slots[s].drain(..) {
                debug_assert!(ev.0 >= at, "event at {} stuck in the past (now {at})", ev.0);
                if ev.0 == at {
                    due.push(ev);
                } else {
                    keep.push(ev);
                }
            }
            std::mem::swap(&mut self.slots[s], &mut keep);
            keep.clear();
            self.free.push(keep);
        }
        self.count -= due.len();
        due
    }

    /// Return bucket storage obtained from [`EventWheel::take_due`].
    pub fn recycle(&mut self, mut storage: Vec<(Cycle, T)>) {
        storage.clear();
        self.free.push(storage);
    }

    /// Total number of queued events.
    pub fn len(&self) -> usize {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn horizon_rounds_to_power_of_two() {
        assert_eq!(EventWheel::<u32>::with_horizon(5).horizon(), 8);
        assert_eq!(EventWheel::<u32>::with_horizon(8).horizon(), 8);
        assert_eq!(EventWheel::<u32>::with_horizon(0).horizon(), 2);
    }

    #[test]
    fn push_all_keeps_fifo_order_with_interleaved_push() {
        let mut w = EventWheel::with_horizon(8);
        w.push(4, "a");
        w.push_all([(4, "b"), (5, "x"), (4, "c")]);
        w.push(4, "d");
        let due = w.take_due(4);
        let got: Vec<_> = due.iter().map(|&(_, x)| x).collect();
        assert_eq!(got, ["a", "b", "c", "d"]);
        w.recycle(due);
        assert_eq!(w.take_due(5)[0].1, "x");
    }

    #[test]
    fn fifo_within_a_cycle() {
        let mut w = EventWheel::with_horizon(8);
        w.push(3, "a");
        w.push(3, "b");
        w.push(3, "c");
        let due = w.take_due(3);
        let got: Vec<_> = due.iter().map(|&(_, x)| x).collect();
        assert_eq!(got, ["a", "b", "c"]);
        assert!(w.is_empty());
        w.recycle(due);
    }

    #[test]
    fn due_only_at_exact_cycle() {
        let mut w = EventWheel::with_horizon(8);
        w.push(2, 1u32);
        w.push(5, 2u32);
        assert!(w.take_due(1).is_empty());
        assert_eq!(w.take_due(2).len(), 1);
        assert_eq!(w.len(), 1);
        assert_eq!(w.take_due(5)[0].1, 2);
        assert!(w.is_empty());
    }

    #[test]
    fn push_then_take_same_cycle() {
        // The NoC pushes credit returns at `now + 1` and drains that same
        // slot at the end of the step.
        let mut w = EventWheel::with_horizon(4);
        w.push(7, "x");
        assert_eq!(w.take_due(7).len(), 1);
    }

    #[test]
    fn wrap_around_reuses_buckets() {
        let mut w = EventWheel::with_horizon(4); // 4 buckets
        w.push(1, "lap0");
        let d = w.take_due(1);
        assert_eq!(d[0].1, "lap0");
        w.recycle(d);
        w.push(5, "lap1"); // same bucket as cycle 1
        assert!(w.take_due(4).is_empty());
        assert_eq!(w.take_due(5)[0].1, "lap1");
    }

    #[test]
    fn lap_collision_partitions_and_retains_order() {
        let mut w = EventWheel::with_horizon(4); // bucket = t & 3
        w.push(2, "now");
        w.push(6, "next-lap-a"); // same bucket (6 & 3 == 2)
        w.push(10, "lap-after"); // same bucket again
        w.push(2, "now-2");
        let due = w.take_due(2);
        let got: Vec<_> = due.iter().map(|&(_, x)| x).collect();
        assert_eq!(got, ["now", "now-2"]);
        assert_eq!(w.len(), 2);
        w.recycle(due);
        let due = w.take_due(6);
        assert_eq!(due[0].1, "next-lap-a");
        assert_eq!(w.take_due(10)[0].1, "lap-after");
        assert!(w.is_empty());
    }

    #[test]
    fn recycled_storage_is_reused() {
        let mut w = EventWheel::with_horizon(4);
        w.push(1, 9u64);
        let d = w.take_due(1);
        let cap_before = d.capacity();
        w.recycle(d);
        w.push(2, 10u64);
        let d = w.take_due(2);
        assert!(d.capacity() >= cap_before);
        assert_eq!(d[0].1, 10);
    }

    #[test]
    fn far_future_events_survive_many_laps() {
        let mut w = EventWheel::with_horizon(2);
        w.push(1000, 42u32);
        for t in 0..1000 {
            assert!(w.take_due(t).is_empty(), "t={t}");
        }
        assert_eq!(w.take_due(1000)[0].1, 42);
    }
}
