//! Scalar RISC-V core model: the general-purpose-processor fallback of
//! the fabric (paper Sec. III "GPPs, in particular based on RISC-V") and
//! the fetch-to-core baseline every accelerator is compared against.

use crate::metrics::{Area, Category, Metrics, Roofline};

use super::{Accelerator, Compute, Precision};

/// In-order RISC-V core with a small SIMD unit.
#[derive(Debug, Clone)]
pub struct CpuCore {
    pub freq_ghz: f64,
    /// MACs retired per cycle (RVV-lite: 4 int8 / 2 f32).
    pub macs_per_cycle_int8: f64,
    pub macs_per_cycle_f32: f64,
    /// Core energy per cycle, pJ (pipeline + regfile + I$).
    pub e_cycle_pj: f64,
    /// D$ access energy, pJ/byte.
    pub e_dcache_pj_byte: f64,
    pub feed_gbs: f64,
}

impl Default for CpuCore {
    fn default() -> Self {
        CpuCore {
            freq_ghz: 1.0,
            macs_per_cycle_int8: 4.0,
            macs_per_cycle_f32: 2.0,
            e_cycle_pj: 20.0,
            e_dcache_pj_byte: 1.2,
            feed_gbs: 8.0,
        }
    }
}

impl Accelerator for CpuCore {
    fn name(&self) -> &'static str {
        "riscv-cpu"
    }

    fn supports(&self, p: Precision) -> bool {
        matches!(p, Precision::F32 | Precision::Int8)
    }

    fn cost(&self, c: &Compute, p: Precision) -> Metrics {
        debug_assert!(self.supports(p));
        let mut m = Metrics::new();
        m.ops = c.ops();
        let rate = match p {
            Precision::Int8 => self.macs_per_cycle_int8,
            _ => self.macs_per_cycle_f32,
        };
        let compute_cycles = (c.ops() as f64 / rate).ceil() as u64;
        let feed_cycles = ((c.io_bytes(p) + c.weight_bytes(p)) as f64
            / (self.feed_gbs / self.freq_ghz))
            .ceil() as u64;
        m.cycles = compute_cycles.max(feed_cycles).max(1);
        m.add_energy(Category::Compute, m.cycles as f64 * self.e_cycle_pj);
        m.add_energy(
            Category::Sram,
            (c.io_bytes(p) + c.weight_bytes(p)) as f64 * self.e_dcache_pj_byte,
        );
        m.bytes_moved = c.io_bytes(p);
        m
    }

    fn area(&self) -> Area {
        Area::new(0.5)
    }

    fn freq_ghz(&self) -> f64 {
        self.freq_ghz
    }

    fn roofline(&self) -> Roofline {
        Roofline {
            peak_ops: self.macs_per_cycle_int8 * self.freq_ghz * 1e9,
            mem_bw: self.feed_gbs * 1e9,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_of_magnitude_slower_than_npu() {
        let cpu = CpuCore::default();
        let npu = super::super::DigitalNpu::default();
        let c = Compute::MatMul { m: 128, k: 256, n: 128 };
        let cc = cpu.cost(&c, Precision::Int8);
        let nc = npu.cost(&c, Precision::Int8);
        assert!(cc.cycles > 100 * nc.cycles, "cpu {} npu {}", cc.cycles, nc.cycles);
    }

    #[test]
    fn int8_twice_the_rate_of_f32() {
        let cpu = CpuCore::default();
        let c = Compute::MatMul { m: 64, k: 64, n: 64 };
        let i8c = cpu.cost(&c, Precision::Int8);
        let f32c = cpu.cost(&c, Precision::F32);
        assert!(f32c.cycles >= 2 * i8c.cycles - 2);
    }

    #[test]
    fn elementwise_is_cheapish() {
        let cpu = CpuCore::default();
        let m = cpu.cost(&Compute::Elementwise { elems: 1000 }, Precision::F32);
        assert!(m.cycles >= 250 && m.cycles <= 2100, "{}", m.cycles);
    }
}
