//! NVM analog crossbar model (ISAAC / PUMA / PRIME class; the paper's
//! "neural accelerators based on non-volatile memory").
//!
//! Weights live as conductances on T×T arrays (weights-stationary). One
//! array read = one analog MVM over a T-row slice: DACs drive the rows,
//! columns integrate, ADCs digitize each column. Energy is DAC/ADC
//! dominated (the well-known analog-accelerator tax); latency is the
//! integration + ADC conversion time per read. Functional twin:
//! python/compile/kernels/crossbar.py (same T=ANALOG_TILE_K semantics).

use crate::metrics::{Area, Category, Metrics, Roofline};

use super::{Accelerator, Compute, Precision};

/// Analog NVM crossbar macro array.
#[derive(Debug, Clone)]
pub struct CrossbarNvm {
    /// Array edge T (T×T cells).
    pub size: usize,
    /// Parallel arrays in the macro.
    pub arrays: usize,
    /// Read (integration + conversion) time, ns.
    pub read_ns: f64,
    /// Energy per ADC conversion, pJ (8-bit SAR: ~2 pJ).
    pub e_adc_pj: f64,
    /// Energy per DAC-driven row, pJ.
    pub e_dac_pj: f64,
    /// Cell read energy, pJ per cell per read.
    pub e_cell_pj: f64,
    /// Input stream bandwidth, GB/s.
    pub feed_gbs: f64,
}

impl Default for CrossbarNvm {
    fn default() -> Self {
        CrossbarNvm {
            size: 128,
            arrays: 8,
            read_ns: 100.0,
            e_adc_pj: 2.0,
            e_dac_pj: 0.5,
            e_cell_pj: 0.001,
            feed_gbs: 8.0,
        }
    }
}

impl CrossbarNvm {
    /// Device clock = one array read per cycle.
    fn reads_for(&self, m: usize, k: usize, n: usize) -> u64 {
        let row_tiles = k.div_ceil(self.size) as u64;
        let col_tiles = n.div_ceil(self.size) as u64;
        m as u64 * row_tiles * col_tiles
    }
}

impl Accelerator for CrossbarNvm {
    fn name(&self) -> &'static str {
        "nvm-crossbar"
    }

    fn supports(&self, p: Precision) -> bool {
        p == Precision::Analog
    }

    fn cost(&self, c: &Compute, p: Precision) -> Metrics {
        debug_assert!(self.supports(p));
        let mut m = Metrics::new();
        m.ops = c.ops();
        match *c {
            Compute::MatMul { m: mm, k, n } => {
                let reads = self.reads_for(mm, k, n);
                // `arrays` reads proceed in parallel.
                m.cycles = reads.div_ceil(self.arrays as u64).max(1);
                // Per read: size DAC drives, size ADC conversions,
                // size*size cell reads.
                let per_read = self.size as f64 * (self.e_dac_pj + self.e_adc_pj)
                    + (self.size * self.size) as f64 * self.e_cell_pj;
                m.add_energy(Category::Adc, reads as f64 * self.size as f64 * self.e_adc_pj);
                m.add_energy(
                    Category::Compute,
                    reads as f64 * (per_read - self.size as f64 * self.e_adc_pj),
                );
            }
            Compute::Elementwise { elems } => {
                // Analog macros defer elementwise to their digital
                // periphery: slow and cheap.
                m.cycles = elems as u64;
                m.add_energy(Category::Compute, elems as f64 * 0.05);
            }
            Compute::SpikingLayer { synapses, activity } => {
                let reads = ((synapses as f64 * activity)
                    / (self.size * self.size) as f64)
                    .ceil() as u64;
                m.cycles = reads.max(1);
                m.add_energy(Category::Adc, reads as f64 * self.size as f64 * self.e_adc_pj);
            }
        }
        m.bytes_moved = c.io_bytes(p);
        m
    }

    fn area(&self) -> Area {
        // NVM cells are tiny; ADCs dominate macro area (~0.5 mm² per
        // 128-ADC bank in 28nm-class analog).
        Area::new(self.arrays as f64 * (0.05 + 0.5))
    }

    /// One "cycle" = one array-read slot.
    fn freq_ghz(&self) -> f64 {
        1.0 / self.read_ns
    }

    fn roofline(&self) -> Roofline {
        let reads_per_s = self.arrays as f64 * 1e9 / self.read_ns;
        Roofline {
            peak_ops: reads_per_s * (self.size * self.size) as f64,
            mem_bw: self.feed_gbs * 1e9,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weights_stationary_energy_independent_of_weight_size_reuse() {
        // Same activations through a bigger weight matrix costs linearly
        // more reads (no weight traffic — conductances are resident).
        let x = CrossbarNvm::default();
        let small = x.cost(&Compute::MatMul { m: 64, k: 128, n: 128 }, Precision::Analog);
        let big = x.cost(&Compute::MatMul { m: 64, k: 256, n: 128 }, Precision::Analog);
        let e_ratio = big.total_energy_pj() / small.total_energy_pj();
        assert!((e_ratio - 2.0).abs() < 0.05, "{e_ratio}");
    }

    #[test]
    fn adc_dominates_energy() {
        let x = CrossbarNvm::default();
        let m = x.cost(&Compute::MatMul { m: 128, k: 128, n: 128 }, Precision::Analog);
        let adc = m.energy(Category::Adc);
        assert!(adc > 0.5 * m.total_energy_pj(), "adc {adc} of {}", m.total_energy_pj());
    }

    #[test]
    fn sub_pj_per_mac() {
        // ISAAC-class headline: well under 1 pJ/MAC for full-tile MVMs.
        let x = CrossbarNvm::default();
        assert!(x.pj_per_mac() < 1.0, "{}", x.pj_per_mac());
        assert!(x.pj_per_mac() > 0.001);
    }

    #[test]
    fn partial_tiles_waste_reads() {
        let x = CrossbarNvm::default();
        let full = x.cost(&Compute::MatMul { m: 1, k: 128, n: 128 }, Precision::Analog);
        let ragged = x.cost(&Compute::MatMul { m: 1, k: 129, n: 129 }, Precision::Analog);
        assert!(ragged.total_energy_pj() > 3.0 * full.total_energy_pj());
    }
}
