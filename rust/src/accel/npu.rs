//! Digital NPU model: an S×S output-stationary systolic MAC array with a
//! double-buffered SRAM operand path (Marsellus / Gemmini / PULP-cluster
//! NPU class — the "conventional digital NPU" tile of paper Fig. 1).

use crate::metrics::{Area, Category, Metrics, Roofline};

use super::{Accelerator, Compute, Precision};

/// Systolic-array digital NPU.
#[derive(Debug, Clone)]
pub struct DigitalNpu {
    /// Array edge (S×S MACs).
    pub size: usize,
    pub freq_ghz: f64,
    /// Energy per int8 MAC, pJ (7nm-class digital: ~0.05-0.1).
    pub e_mac_int8_pj: f64,
    /// f32 MAC multiplier vs int8 (energy and half the lanes).
    pub f32_factor: f64,
    /// Local SRAM access energy, pJ/byte.
    pub e_sram_pj_byte: f64,
    /// Operand feed bandwidth, bytes/cycle.
    pub feed_bytes_cycle: f64,
}

impl Default for DigitalNpu {
    fn default() -> Self {
        DigitalNpu {
            size: 128,
            freq_ghz: 1.0,
            e_mac_int8_pj: 0.08,
            f32_factor: 4.0,
            e_sram_pj_byte: 0.6,
            feed_bytes_cycle: 256.0,
        }
    }
}

impl Accelerator for DigitalNpu {
    fn name(&self) -> &'static str {
        "digital-npu"
    }

    fn supports(&self, p: Precision) -> bool {
        matches!(p, Precision::F32 | Precision::Int8)
    }

    fn cost(&self, c: &Compute, p: Precision) -> Metrics {
        debug_assert!(self.supports(p));
        let mut m = Metrics::new();
        m.ops = c.ops();
        match *c {
            Compute::MatMul { m: mm, k, n } => {
                let s = self.size;
                // f32 runs at quarter rate (lane pairing + wider MACs).
                let rate_penalty = if p == Precision::F32 { 4 } else { 1 };
                let tiles_m = mm.div_ceil(s);
                let tiles_n = n.div_ceil(s);
                // Output-stationary: each (s, s) output tile streams K
                // operand pairs; pipeline fill adds 2S.
                let per_tile = k + 2 * s;
                let compute = (tiles_m * tiles_n * per_tile * rate_penalty) as u64;
                // Feed constraint: operands must cross the SRAM port.
                let feed = (c.io_bytes(p) + c.weight_bytes(p)) as f64 / self.feed_bytes_cycle;
                m.cycles = compute.max(feed.ceil() as u64);
                let e_mac = match p {
                    Precision::Int8 => self.e_mac_int8_pj,
                    _ => self.e_mac_int8_pj * self.f32_factor,
                };
                m.add_energy(Category::Compute, c.ops() as f64 * e_mac);
                m.add_energy(
                    Category::Sram,
                    (c.io_bytes(p) + c.weight_bytes(p)) as f64 * self.e_sram_pj_byte,
                );
            }
            Compute::Elementwise { elems } => {
                // Vector unit: one lane-row per cycle.
                m.cycles = (elems.div_ceil(self.size)) as u64;
                m.add_energy(Category::Compute, elems as f64 * 0.02);
                m.add_energy(Category::Sram, c.io_bytes(p) as f64 * self.e_sram_pj_byte);
            }
            Compute::SpikingLayer { .. } => {
                // Dense fallback: evaluate all synapses.
                let syn = match *c {
                    Compute::SpikingLayer { synapses, .. } => synapses,
                    _ => unreachable!(),
                };
                m.cycles = (syn.div_ceil(self.size * self.size)) as u64;
                m.add_energy(Category::Compute, syn as f64 * self.e_mac_int8_pj);
            }
        }
        m.bytes_moved = c.io_bytes(p);
        m
    }

    fn area(&self) -> Area {
        // ~0.0006 mm²/int8 MAC + SRAM macro overhead (7nm-class).
        Area::new(self.size as f64 * self.size as f64 * 0.0006 + 1.5)
    }

    fn freq_ghz(&self) -> f64 {
        self.freq_ghz
    }

    fn roofline(&self) -> Roofline {
        Roofline {
            peak_ops: (self.size * self.size) as f64 * self.freq_ghz * 1e9,
            mem_bw: self.feed_bytes_cycle * self.freq_ghz * 1e9,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_tile_utilization_near_peak() {
        let npu = DigitalNpu::default();
        let c = Compute::MatMul { m: 128, k: 1024, n: 128 };
        let m = npu.cost(&c, Precision::Int8);
        let achieved = m.tops(npu.freq_ghz()) * 1e12;
        let eff = npu.roofline().efficiency(
            c.ops() as f64 / (c.io_bytes(Precision::Int8) + c.weight_bytes(Precision::Int8)) as f64,
            achieved,
        );
        assert!(eff > 0.7, "eff {eff}");
    }

    #[test]
    fn small_matmul_underutilizes() {
        let npu = DigitalNpu::default();
        let big = npu.cost(&Compute::MatMul { m: 128, k: 512, n: 128 }, Precision::Int8);
        let small = npu.cost(&Compute::MatMul { m: 8, k: 512, n: 8 }, Precision::Int8);
        let tput = |m: &Metrics| m.ops as f64 / m.cycles as f64;
        assert!(tput(&big) > 50.0 * tput(&small), "{} {}", tput(&big), tput(&small));
    }

    #[test]
    fn f32_slower_and_hungrier_than_int8() {
        let npu = DigitalNpu::default();
        let c = Compute::MatMul { m: 128, k: 256, n: 128 };
        let i8c = npu.cost(&c, Precision::Int8);
        let f32c = npu.cost(&c, Precision::F32);
        assert!(f32c.cycles > i8c.cycles);
        assert!(f32c.total_energy_pj() > i8c.total_energy_pj());
    }

    #[test]
    fn rejects_analog() {
        assert!(!DigitalNpu::default().supports(Precision::Analog));
    }
}
