//! Post-CMOS accelerator models (paper Sec. II).
//!
//! One analytic latency/energy/area model per accelerator family the
//! ARCHYTAS project targets, all behind the [`Accelerator`] trait so the
//! fabric, mapper and DSE treat them uniformly:
//!
//! * [`DigitalNpu`] — systolic-array digital NPU (the "conventional"
//!   baseline tile, Marsellus/PULP-class).
//! * [`CrossbarNvm`] — non-volatile-memory analog crossbar (ISAAC/PUMA
//!   class): weights stationary as conductances, DAC/ADC dominated.
//! * [`Photonic`] — photonic tensor core (Feldmann'21 / Xu'21 class):
//!   WDM-parallel MVM at modulator rate, laser + ADC overheads.
//! * [`Neuromorphic`] — event-driven SNN core (Loihi-class): energy
//!   proportional to spike traffic.
//! * [`CpuCore`] — scalar RISC-V core (the GPP fallback and the
//!   fetch-to-core baseline).
//!
//! The *functional* twin of the analog models is the Pallas crossbar
//! kernel (python/compile/kernels/crossbar.py); constants here and there
//! are kept in sync (ANALOG_* in model.py).

mod cpu;
mod crossbar;
mod neuromorphic;
mod npu;
mod photonic;

pub use cpu::CpuCore;
pub use crossbar::CrossbarNvm;
pub use neuromorphic::Neuromorphic;
pub use npu::DigitalNpu;
pub use photonic::Photonic;

use crate::metrics::{Area, Metrics, Roofline};

/// Numeric precision a compute op runs at.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    F32,
    Int8,
    /// Analog compute (level-quantized weights + ADC read-out).
    Analog,
}

/// Device-independent compute descriptor (what the mapper hands to a CU).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Compute {
    /// Dense matmul `[m,k] x [k,n]`.
    MatMul { m: usize, k: usize, n: usize },
    /// Elementwise / activation over `elems` values.
    Elementwise { elems: usize },
    /// Event-driven SNN layer: `synapses` potential connections at
    /// `activity` spike rate.
    SpikingLayer { synapses: usize, activity: f64 },
}

impl Compute {
    /// Nominal op count (MACs for matmul, 1/elem otherwise).
    pub fn ops(&self) -> u64 {
        match self {
            Compute::MatMul { m, k, n } => (*m as u64) * (*k as u64) * (*n as u64),
            Compute::Elementwise { elems } => *elems as u64,
            Compute::SpikingLayer { synapses, activity } => {
                (*synapses as f64 * activity) as u64
            }
        }
    }

    /// Input + output bytes at the given precision (weights excluded —
    /// weight residency is the tile's concern).
    pub fn io_bytes(&self, p: Precision) -> u64 {
        let b = match p {
            Precision::F32 => 4,
            Precision::Int8 | Precision::Analog => 1,
        };
        match self {
            Compute::MatMul { m, k, n } => ((m * k + m * n) as u64) * b,
            Compute::Elementwise { elems } => 2 * (*elems as u64) * b,
            Compute::SpikingLayer { synapses, .. } => (*synapses as u64) / 8,
        }
    }

    /// Weight bytes (stationary data a tile must hold or stream).
    pub fn weight_bytes(&self, p: Precision) -> u64 {
        let b = match p {
            Precision::F32 => 4,
            Precision::Int8 | Precision::Analog => 1,
        };
        match self {
            Compute::MatMul { k, n, .. } => (*k as u64) * (*n as u64) * b,
            _ => 0,
        }
    }
}

/// The common accelerator interface.
pub trait Accelerator: Send + Sync {
    fn name(&self) -> &'static str;

    /// Whether this device can run ops at precision `p`.
    fn supports(&self, p: Precision) -> bool;

    /// Latency (device cycles at `freq_ghz`) and energy for one compute.
    /// Implementations must set `cycles`, `ops` and energy categories.
    fn cost(&self, c: &Compute, p: Precision) -> Metrics;

    /// Silicon (or photonic die) area.
    fn area(&self) -> Area;

    /// Device clock, GHz.
    fn freq_ghz(&self) -> f64;

    /// Peak throughput / feed bandwidth for roofline sanity checks.
    fn roofline(&self) -> Roofline;

    /// pJ per MAC at the device's preferred precision (headline metric).
    fn pj_per_mac(&self) -> f64 {
        let c = Compute::MatMul { m: 128, k: 128, n: 128 };
        let p = if self.supports(Precision::Analog) {
            Precision::Analog
        } else if self.supports(Precision::Int8) {
            Precision::Int8
        } else {
            Precision::F32
        };
        let m = self.cost(&c, p);
        m.total_energy_pj() / c.ops() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compute_ops_and_bytes() {
        let mm = Compute::MatMul { m: 4, k: 8, n: 2 };
        assert_eq!(mm.ops(), 64);
        assert_eq!(mm.io_bytes(Precision::F32), (32 + 8) * 4);
        assert_eq!(mm.io_bytes(Precision::Int8), 40);
        assert_eq!(mm.weight_bytes(Precision::F32), 64);
        let ew = Compute::Elementwise { elems: 10 };
        assert_eq!(ew.ops(), 10);
        assert_eq!(ew.weight_bytes(Precision::F32), 0);
        let sp = Compute::SpikingLayer { synapses: 1000, activity: 0.1 };
        assert_eq!(sp.ops(), 100);
    }

    /// Cross-device headline relations the paper leans on (E1/E7 shape):
    /// analog/photonic MVM beats digital on pJ/MAC; everything beats the
    /// scalar CPU.
    #[test]
    fn efficiency_ordering() {
        let npu = DigitalNpu::default();
        let xbar = CrossbarNvm::default();
        let pho = Photonic::default();
        let cpu = CpuCore::default();
        assert!(xbar.pj_per_mac() < npu.pj_per_mac(), "crossbar < npu");
        assert!(pho.pj_per_mac() < npu.pj_per_mac(), "photonic < npu");
        assert!(npu.pj_per_mac() < cpu.pj_per_mac(), "npu < cpu");
    }

    #[test]
    fn rooflines_are_positive_and_consistent() {
        let devs: Vec<Box<dyn Accelerator>> = vec![
            Box::new(DigitalNpu::default()),
            Box::new(CrossbarNvm::default()),
            Box::new(Photonic::default()),
            Box::new(Neuromorphic::default()),
            Box::new(CpuCore::default()),
        ];
        for d in devs {
            let r = d.roofline();
            assert!(r.peak_ops > 0.0 && r.mem_bw > 0.0, "{}", d.name());
            assert!(d.area().mm2 > 0.0);
            assert!(d.freq_ghz() > 0.0);
        }
    }
}
