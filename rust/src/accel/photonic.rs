//! Photonic tensor core model (the paper's "Processing-On-the-Flight"
//! accelerator; Feldmann'21 photonic tensor core and Xu'21 11-TOPS
//! convolutional accelerator are the calibration points).
//!
//! An N×N coherent mesh (or N-wavelength WDM bank) performs one N-vector
//! MVM per modulator clock once the weights are programmed (thermo-optic
//! phase shifters — slow to program, weights-stationary at inference).
//! Throughput scales as N² × modulator rate; the energy floor is set not
//! by the optics (≈ free) but by the electrical periphery: modulators,
//! ADCs at the readout, and the continuous laser power.

use crate::metrics::{Area, Category, Metrics, Roofline};

use super::{Accelerator, Compute, Precision};

/// Photonic MVM engine.
#[derive(Debug, Clone)]
pub struct Photonic {
    /// Optical port count N (mesh edge / WDM channels).
    pub size: usize,
    /// Modulator clock, GHz (10+ GHz is routine).
    pub mod_rate_ghz: f64,
    /// Laser wall power, mW (continuous while the engine is on).
    pub laser_mw: f64,
    /// Wall-plug laser efficiency already folded into `laser_mw`.
    /// Energy per modulator toggle, pJ.
    pub e_mod_pj: f64,
    /// Energy per readout ADC conversion, pJ (high-speed: ~1-2 pJ).
    pub e_adc_pj: f64,
    /// Electrical feed bandwidth, GB/s.
    pub feed_gbs: f64,
    /// Thermo-optic weight programming/settling time per weight-tile
    /// load, microseconds (phase shifters are slow — the reason photonic
    /// engines are weights-stationary).
    pub program_us: f64,
    /// Weight-residency reuse factor: how many calls a programmed tile
    /// serves before reprogramming (inference batching). Programming cost
    /// is amortized by this factor.
    pub reuse: u64,
}

impl Default for Photonic {
    fn default() -> Self {
        Photonic {
            size: 64,
            mod_rate_ghz: 10.0,
            laser_mw: 100.0,
            e_mod_pj: 0.3,
            e_adc_pj: 1.5,
            feed_gbs: 64.0,
            program_us: 5.0,
            reuse: 64,
        }
    }
}

impl Accelerator for Photonic {
    fn name(&self) -> &'static str {
        "photonic"
    }

    fn supports(&self, p: Precision) -> bool {
        p == Precision::Analog
    }

    fn cost(&self, c: &Compute, p: Precision) -> Metrics {
        debug_assert!(self.supports(p));
        let mut m = Metrics::new();
        m.ops = c.ops();
        match *c {
            Compute::MatMul { m: mm, k, n } => {
                let row_tiles = k.div_ceil(self.size) as u64;
                let col_tiles = n.div_ceil(self.size) as u64;
                // One MVM slice per modulator clock; weight reprogramming
                // between column tiles is amortized (weights-stationary
                // inference: col_tiles small).
                let shots = mm as u64 * row_tiles * col_tiles;
                // Each distinct weight tile must be programmed once
                // (thermo-optic settle, laser burning), amortized over
                // `reuse` calls of the same resident weights.
                let program_cycles =
                    (self.program_us * 1e-6 * self.mod_rate_ghz * 1e9).ceil() as u64
                        * row_tiles
                        * col_tiles
                        / self.reuse.max(1);
                m.cycles = shots.max(1) + program_cycles;
                // Per shot: N modulator toggles + N ADC conversions.
                m.add_energy(Category::Adc, shots as f64 * self.size as f64 * self.e_adc_pj);
                m.add_energy(
                    Category::Compute,
                    shots as f64 * self.size as f64 * self.e_mod_pj,
                );
                // Laser burns continuously for the duration.
                let dur_s = m.cycles as f64 / (self.mod_rate_ghz * 1e9);
                m.add_energy(Category::Laser, self.laser_mw * 1e-3 * dur_s * 1e12);
            }
            Compute::Elementwise { elems } => {
                // No optical nonlinearity assumed: digital periphery.
                m.cycles = elems.div_ceil(self.size) as u64;
                m.add_energy(Category::Compute, elems as f64 * 0.02);
            }
            Compute::SpikingLayer { synapses, activity } => {
                let shots = ((synapses as f64 * activity) / (self.size * self.size) as f64)
                    .ceil() as u64;
                m.cycles = shots.max(1);
                m.add_energy(Category::Adc, shots as f64 * self.size as f64 * self.e_adc_pj);
            }
        }
        m.bytes_moved = c.io_bytes(p);
        m
    }

    fn area(&self) -> Area {
        // Photonic meshes are big: ~(N * 60um)² of silicon photonics
        // + ADC bank.
        let edge_mm = self.size as f64 * 0.06;
        Area::new(edge_mm * edge_mm + 1.0)
    }

    fn freq_ghz(&self) -> f64 {
        self.mod_rate_ghz
    }

    fn roofline(&self) -> Roofline {
        Roofline {
            peak_ops: (self.size * self.size) as f64 * self.mod_rate_ghz * 1e9,
            mem_bw: self.feed_gbs * 1e9,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multi_tops_at_full_tilt() {
        // Xu'21 headline shape: N=64 @ 10 GHz => 40+ TOPS peak.
        let p = Photonic::default();
        assert!(p.roofline().peak_ops > 10e12, "{}", p.roofline().peak_ops);
        let c = Compute::MatMul { m: 4096, k: 64, n: 64 };
        let m = p.cost(&c, Precision::Analog);
        let tops = m.tops(p.freq_ghz());
        assert!(tops > 10.0, "{tops}");
    }

    #[test]
    fn laser_overhead_dominates_small_batches() {
        // Single small MVM: the laser + ADC tax swamps the useful work —
        // the crossover the E7 bench sweeps.
        let p = Photonic::default();
        let small = p.cost(&Compute::MatMul { m: 1, k: 64, n: 64 }, Precision::Analog);
        let big = p.cost(&Compute::MatMul { m: 4096, k: 64, n: 64 }, Precision::Analog);
        let pj_small = small.total_energy_pj() / small.ops as f64;
        let pj_big = big.total_energy_pj() / big.ops as f64;
        assert!(pj_small > pj_big, "{pj_small} vs {pj_big}");
    }

    #[test]
    fn adc_plus_mod_set_energy_floor() {
        let p = Photonic::default();
        let m = p.cost(&Compute::MatMul { m: 1024, k: 64, n: 64 }, Precision::Analog);
        let periph = m.energy(Category::Adc) + m.energy(Category::Compute);
        assert!(periph > 0.6 * m.total_energy_pj());
    }
}
