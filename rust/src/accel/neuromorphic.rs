//! Neuromorphic (event-driven SNN) core model, Loihi-class: energy and
//! time scale with *spike traffic*, not with the dense synapse count —
//! the activity-proportionality that experiment E9 sweeps.

use crate::metrics::{Area, Category, Metrics, Roofline};

use super::{Accelerator, Compute, Precision};

/// Event-driven spiking neural core.
#[derive(Debug, Clone)]
pub struct Neuromorphic {
    /// Synaptic events processed per cycle.
    pub events_per_cycle: f64,
    pub freq_ghz: f64,
    /// Energy per synaptic event, pJ (Loihi: ~23 pJ incl. overheads;
    /// newer cores ~1-5).
    pub e_event_pj: f64,
    /// Energy per neuron update, pJ.
    pub e_neuron_pj: f64,
    /// Static/idle power share, pJ per cycle.
    pub e_idle_pj_cycle: f64,
    /// Feed bandwidth (spike packets), GB/s.
    pub feed_gbs: f64,
}

impl Default for Neuromorphic {
    fn default() -> Self {
        Neuromorphic {
            events_per_cycle: 8.0,
            freq_ghz: 0.5,
            e_event_pj: 4.0,
            e_neuron_pj: 1.0,
            e_idle_pj_cycle: 2.0,
            feed_gbs: 4.0,
        }
    }
}

impl Accelerator for Neuromorphic {
    fn name(&self) -> &'static str {
        "neuromorphic"
    }

    fn supports(&self, p: Precision) -> bool {
        // Spiking cores are their own numeric regime; we bucket them with
        // Analog (non-exact) precision.
        p == Precision::Analog
    }

    fn cost(&self, c: &Compute, p: Precision) -> Metrics {
        debug_assert!(self.supports(p));
        let mut m = Metrics::new();
        m.ops = c.ops();
        match *c {
            Compute::SpikingLayer { synapses, activity } => {
                let events = (synapses as f64 * activity).ceil();
                m.cycles = ((events / self.events_per_cycle).ceil() as u64).max(1);
                m.add_energy(Category::Compute, events * self.e_event_pj);
                // Neuron updates: ~sqrt(synapses) neurons as a first-order
                // fanout model.
                let neurons = (synapses as f64).sqrt();
                m.add_energy(Category::Compute, neurons * self.e_neuron_pj);
                m.add_energy(Category::Leakage, m.cycles as f64 * self.e_idle_pj_cycle);
            }
            // Rate-coded fallback for non-spiking ops: every MAC becomes
            // ~activity=1 events (dense) — deliberately unattractive, the
            // mapper should not put dense GEMMs here.
            Compute::MatMul { .. } | Compute::Elementwise { .. } => {
                let events = c.ops() as f64;
                m.cycles = ((events / self.events_per_cycle).ceil() as u64).max(1);
                m.add_energy(Category::Compute, events * self.e_event_pj);
                m.add_energy(Category::Leakage, m.cycles as f64 * self.e_idle_pj_cycle);
            }
        }
        m.bytes_moved = c.io_bytes(p);
        m
    }

    fn area(&self) -> Area {
        Area::new(2.0)
    }

    fn freq_ghz(&self) -> f64 {
        self.freq_ghz
    }

    fn roofline(&self) -> Roofline {
        Roofline {
            peak_ops: self.events_per_cycle * self.freq_ghz * 1e9,
            mem_bw: self.feed_gbs * 1e9,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_proportional_to_activity() {
        let n = Neuromorphic::default();
        let lo = n.cost(&Compute::SpikingLayer { synapses: 1_000_000, activity: 0.05 },
                        Precision::Analog);
        let hi = n.cost(&Compute::SpikingLayer { synapses: 1_000_000, activity: 0.50 },
                        Precision::Analog);
        let ratio = hi.total_energy_pj() / lo.total_energy_pj();
        assert!((ratio - 10.0).abs() < 1.0, "{ratio}");
    }

    #[test]
    fn sparse_snn_beats_dense_fallback() {
        let n = Neuromorphic::default();
        let sparse = n.cost(&Compute::SpikingLayer { synapses: 1 << 20, activity: 0.05 },
                            Precision::Analog);
        let dense = n.cost(&Compute::MatMul { m: 32, k: 128, n: 256 }, Precision::Analog);
        // Same synapse count (32*128*256 = 2^20) but dense pays full rate.
        assert!(sparse.total_energy_pj() < dense.total_energy_pj() / 10.0);
    }

    #[test]
    fn latency_scales_with_events() {
        let n = Neuromorphic::default();
        let a = n.cost(&Compute::SpikingLayer { synapses: 80_000, activity: 0.1 },
                       Precision::Analog);
        let b = n.cost(&Compute::SpikingLayer { synapses: 800_000, activity: 0.1 },
                       Precision::Analog);
        assert!(b.cycles >= 9 * a.cycles);
    }
}
