//! PJRT engine: HLO-text loading, compilation and execution.
//!
//! The real engine rides on the external `xla` crate, which the offline
//! build image does not ship; it is therefore compiled only with the
//! `pjrt` cargo feature (which additionally requires adding the `xla`
//! dependency to Cargo.toml). The default build substitutes a stub with
//! the same API whose constructor reports the runtime as unavailable —
//! callers like `Runtime::open_default()` then fail cleanly at open
//! time, and every test that needs artifacts skips or is feature-gated.

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use anyhow::{bail, Context};

    use crate::Result;

    use super::super::{ArtifactSpec, ShapeSpec, Tensor};

    /// A PJRT CPU client plus the HLO-text loader.
    pub struct Engine {
        client: xla::PjRtClient,
    }

    impl Engine {
        pub fn cpu() -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Engine { client })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile one artifact. HLO *text* is the interchange
        /// format: jax >= 0.5 emits protos with 64-bit instruction ids
        /// which xla_extension 0.5.1 rejects; the text parser reassigns
        /// ids.
        pub fn load(&self, spec: &ArtifactSpec) -> Result<Executable> {
            let proto = xla::HloModuleProto::from_text_file(
                spec.hlo_path
                    .to_str()
                    .ok_or_else(|| anyhow::anyhow!("non-utf8 path {:?}", spec.hlo_path))?,
            )
            .with_context(|| format!("parsing HLO text {:?}", spec.hlo_path))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {:?}", spec.name))?;
            Ok(Executable {
                name: spec.name.clone(),
                inputs: spec.inputs.clone(),
                outputs: spec.outputs.clone(),
                exe,
            })
        }
    }

    /// A compiled artifact, ready to execute with shape-checked f32
    /// tensors.
    pub struct Executable {
        name: String,
        inputs: Vec<ShapeSpec>,
        outputs: Vec<ShapeSpec>,
        exe: xla::PjRtLoadedExecutable,
    }

    impl Executable {
        pub fn name(&self) -> &str {
            &self.name
        }

        pub fn input_specs(&self) -> &[ShapeSpec] {
            &self.inputs
        }

        pub fn output_specs(&self) -> &[ShapeSpec] {
            &self.outputs
        }

        /// Execute with host tensors; returns the decomposed output tuple
        /// (aot.py lowers with `return_tuple=True`).
        pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
            if inputs.len() != self.inputs.len() {
                bail!(
                    "{}: expected {} inputs, got {}",
                    self.name,
                    self.inputs.len(),
                    inputs.len()
                );
            }
            let mut literals = Vec::with_capacity(inputs.len());
            for (i, (t, spec)) in inputs.iter().zip(&self.inputs).enumerate() {
                if t.dims() != spec.dims.as_slice() {
                    bail!(
                        "{}: input {i} shape {:?} != spec {:?}",
                        self.name,
                        t.dims(),
                        spec.dims
                    );
                }
                let dims_i64: Vec<i64> = t.dims().iter().map(|&d| d as i64).collect();
                let lit = xla::Literal::vec1(t.data());
                let lit = if dims_i64.len() == 1 {
                    lit
                } else {
                    lit.reshape(&dims_i64)
                        .with_context(|| format!("{}: reshaping input {i}", self.name))?
                };
                literals.push(lit);
            }
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .with_context(|| format!("executing {}", self.name))?;
            let tuple = result[0][0]
                .to_literal_sync()
                .with_context(|| format!("{}: fetching result", self.name))?;
            let outs = tuple
                .to_tuple()
                .with_context(|| format!("{}: decomposing result tuple", self.name))?;
            if outs.len() != self.outputs.len() {
                bail!(
                    "{}: expected {} outputs, got {}",
                    self.name,
                    self.outputs.len(),
                    outs.len()
                );
            }
            outs.into_iter()
                .zip(&self.outputs)
                .map(|(lit, spec)| {
                    let data = lit
                        .to_vec::<f32>()
                        .with_context(|| format!("{}: reading output", self.name))?;
                    Tensor::new(spec.dims.clone(), data)
                })
                .collect()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub_impl {
    use anyhow::bail;

    use crate::Result;

    use super::super::{ArtifactSpec, ShapeSpec, Tensor};

    const UNAVAILABLE: &str =
        "PJRT runtime unavailable: archytas was built without the `pjrt` feature \
         (the offline image ships no `xla` crate); timing simulation, DSE and the \
         compiler stack work without it";

    /// API-compatible stand-in for the PJRT engine; construction fails.
    pub struct Engine {
        _private: (),
    }

    impl Engine {
        pub fn cpu() -> Result<Self> {
            bail!("{UNAVAILABLE}")
        }

        pub fn platform(&self) -> String {
            "unavailable".into()
        }

        pub fn load(&self, _spec: &ArtifactSpec) -> Result<Executable> {
            bail!("{UNAVAILABLE}")
        }
    }

    /// Never constructed in stub builds; exists so signatures line up.
    pub struct Executable {
        name: String,
        inputs: Vec<ShapeSpec>,
        outputs: Vec<ShapeSpec>,
    }

    impl Executable {
        pub fn name(&self) -> &str {
            &self.name
        }

        pub fn input_specs(&self) -> &[ShapeSpec] {
            &self.inputs
        }

        pub fn output_specs(&self) -> &[ShapeSpec] {
            &self.outputs
        }

        pub fn run(&self, _inputs: &[Tensor]) -> Result<Vec<Tensor>> {
            bail!("{UNAVAILABLE}")
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::{Engine, Executable};
#[cfg(not(feature = "pjrt"))]
pub use stub_impl::{Engine, Executable};
