//! Host-side tensor type used for artifact I/O.

use anyhow::{bail, Context};

use crate::Result;

/// A dense, row-major f32 tensor. All AOT artifact inputs and outputs are
/// f32 by construction (integer paths are baked *inside* the HLO), which
/// keeps the FFI surface minimal.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    dims: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let n: usize = dims.iter().product();
        if n != data.len() {
            bail!("shape {dims:?} wants {n} elements, got {}", data.len());
        }
        Ok(Tensor { dims, data })
    }

    pub fn zeros(dims: Vec<usize>) -> Self {
        let n = dims.iter().product();
        Tensor { dims, data: vec![0.0; n] }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { dims: vec![], data: vec![v] }
    }

    /// Filled from a deterministic xoshiro stream — the Rust twin of the
    /// seeded numpy generators in the python tests.
    pub fn random(dims: Vec<usize>, rng: &mut crate::sim::Rng) -> Self {
        let n: usize = dims.iter().product();
        let data = (0..n).map(|_| rng.normal() as f32).collect();
        Tensor { dims, data }
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// 2-D accessor (row-major).
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.dims.len(), 2);
        self.data[i * self.dims[1] + j]
    }

    /// Reinterpret with a new shape of equal element count.
    pub fn reshape(mut self, dims: Vec<usize>) -> Result<Self> {
        let n: usize = dims.iter().product();
        if n != self.data.len() {
            bail!("cannot reshape {:?} -> {dims:?}", self.dims);
        }
        self.dims = dims;
        Ok(self)
    }

    /// Max |a-b| over two equal-shaped tensors.
    pub fn max_abs_diff(&self, other: &Tensor) -> Result<f32> {
        if self.dims != other.dims {
            bail!("shape mismatch {:?} vs {:?}", self.dims, other.dims);
        }
        Ok(self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max))
    }

    /// Load raw little-endian f32 from a file (golden binaries).
    pub fn from_f32_file(path: &std::path::Path, dims: Vec<usize>) -> Result<Self> {
        let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        if bytes.len() % 4 != 0 {
            bail!("{path:?}: length {} not a multiple of 4", bytes.len());
        }
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Tensor::new(dims, data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_element_count() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn at2_row_major() {
        let t = Tensor::new(vec![2, 3], (0..6).map(|i| i as f32).collect()).unwrap();
        assert_eq!(t.at2(0, 2), 2.0);
        assert_eq!(t.at2(1, 0), 3.0);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::new(vec![2, 3], (0..6).map(|i| i as f32).collect()).unwrap();
        let r = t.clone().reshape(vec![3, 2]).unwrap();
        assert_eq!(r.data(), t.data());
        assert!(t.reshape(vec![4, 2]).is_err());
    }

    #[test]
    fn max_abs_diff_works() {
        let a = Tensor::new(vec![3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = Tensor::new(vec![3], vec![1.5, 2.0, 2.0]).unwrap();
        assert_eq!(a.max_abs_diff(&b).unwrap(), 1.0);
        let c = Tensor::zeros(vec![2]);
        assert!(a.max_abs_diff(&c).is_err());
    }

    #[test]
    fn golden_file_roundtrip() {
        let dir = std::env::temp_dir().join("archytas_tensor_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bin");
        let vals = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&path, bytes).unwrap();
        let t = Tensor::from_f32_file(&path, vec![3]).unwrap();
        assert_eq!(t.data(), &vals);
    }
}
