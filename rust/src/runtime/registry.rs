//! Artifact registry: parses `artifacts/manifest.toml` (emitted by
//! python/compile/aot.py) into typed specs and loads golden tensors.

use anyhow::{anyhow, bail, Context};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::config::{parse_document, Value};
use crate::Result;

use super::Tensor;

/// Element type of an artifact input/output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    S32,
    S8,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => DType::F32,
            "s32" => DType::S32,
            "s8" => DType::S8,
            other => bail!("unknown dtype {other:?}"),
        })
    }
}

/// Parsed `"f32[4,16,16,3]"`-style shape string.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShapeSpec {
    pub dtype: DType,
    pub dims: Vec<usize>,
}

impl ShapeSpec {
    pub fn parse(s: &str) -> Result<Self> {
        let open = s.find('[').ok_or_else(|| anyhow!("bad shape {s:?}"))?;
        if !s.ends_with(']') {
            bail!("bad shape {s:?}");
        }
        let dtype = DType::parse(&s[..open])?;
        let body = &s[open + 1..s.len() - 1];
        let dims = if body.is_empty() {
            Vec::new()
        } else {
            body.split(',')
                .map(|d| d.trim().parse::<usize>().with_context(|| format!("bad shape {s:?}")))
                .collect::<Result<_>>()?
        };
        Ok(ShapeSpec { dtype, dims })
    }

    pub fn elements(&self) -> usize {
        self.dims.iter().product()
    }
}

/// One artifact: HLO path, I/O shapes, golden files.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub hlo_path: PathBuf,
    pub inputs: Vec<ShapeSpec>,
    pub outputs: Vec<ShapeSpec>,
    pub golden_in: Vec<PathBuf>,
    pub golden_out: Vec<PathBuf>,
}

/// The parsed manifest.
pub struct Registry {
    dir: PathBuf,
    specs: BTreeMap<String, ArtifactSpec>,
}

impl Registry {
    pub fn open(dir: &Path) -> Result<Self> {
        let manifest = dir.join("manifest.toml");
        let text = std::fs::read_to_string(&manifest).with_context(|| {
            format!("reading {manifest:?} — run `make artifacts` first")
        })?;
        Self::from_manifest_text(dir, &text)
    }

    pub fn from_manifest_text(dir: &Path, text: &str) -> Result<Self> {
        let doc = parse_document(text).context("parsing manifest.toml")?;
        let mut specs = BTreeMap::new();
        for row in doc.tables("artifact") {
            let get = |key: &str| -> Result<&Value> {
                crate::config::table_get(row, key)
                    .ok_or_else(|| anyhow!("manifest entry missing {key:?}"))
            };
            let name = get("name")?
                .as_str()
                .ok_or_else(|| anyhow!("name not a string"))?
                .to_string();
            let hlo = get("hlo")?.as_str().ok_or_else(|| anyhow!("hlo not a string"))?;
            let shapes = |key: &str| -> Result<Vec<ShapeSpec>> {
                get(key)?
                    .as_str_array()
                    .ok_or_else(|| anyhow!("{key} not a string array"))?
                    .into_iter()
                    .map(ShapeSpec::parse)
                    .collect()
            };
            let paths = |key: &str| -> Result<Vec<PathBuf>> {
                Ok(get(key)?
                    .as_str_array()
                    .ok_or_else(|| anyhow!("{key} not a string array"))?
                    .into_iter()
                    .map(|p| dir.join(p))
                    .collect())
            };
            let spec = ArtifactSpec {
                name: name.clone(),
                hlo_path: dir.join(hlo),
                inputs: shapes("inputs")?,
                outputs: shapes("outputs")?,
                golden_in: paths("golden_in")?,
                golden_out: paths("golden_out")?,
            };
            if spec.inputs.len() != spec.golden_in.len()
                || spec.outputs.len() != spec.golden_out.len()
            {
                bail!("{name}: golden file count mismatch");
            }
            specs.insert(name, spec);
        }
        if specs.is_empty() {
            bail!("manifest contains no artifacts");
        }
        Ok(Registry { dir: dir.to_path_buf(), specs })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn names(&self) -> Vec<String> {
        self.specs.keys().cloned().collect()
    }

    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.specs
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?} (have: {:?})", self.names()))
    }

    /// Load the golden input tensors of an artifact (f32 files).
    pub fn golden_inputs(&self, name: &str) -> Result<Vec<Tensor>> {
        let spec = self.spec(name)?;
        spec.inputs
            .iter()
            .zip(&spec.golden_in)
            .map(|(shape, path)| {
                if shape.dtype != DType::F32 {
                    bail!("{name}: non-f32 golden input unsupported at runtime");
                }
                Tensor::from_f32_file(path, shape.dims.clone())
            })
            .collect()
    }

    /// Load the golden output tensors of an artifact.
    pub fn golden_outputs(&self, name: &str) -> Result<Vec<Tensor>> {
        let spec = self.spec(name)?;
        spec.outputs
            .iter()
            .zip(&spec.golden_out)
            .map(|(shape, path)| Tensor::from_f32_file(path, shape.dims.clone()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_spec_parses() {
        let s = ShapeSpec::parse("f32[4,16,16,3]").unwrap();
        assert_eq!(s.dtype, DType::F32);
        assert_eq!(s.dims, vec![4, 16, 16, 3]);
        assert_eq!(s.elements(), 4 * 16 * 16 * 3);
        assert_eq!(ShapeSpec::parse("s8[1,2]").unwrap().dtype, DType::S8);
        assert_eq!(ShapeSpec::parse("f32[]").unwrap().dims, Vec::<usize>::new());
        assert!(ShapeSpec::parse("f64[2]").is_err());
        assert!(ShapeSpec::parse("f32[2").is_err());
        assert!(ShapeSpec::parse("f32[a]").is_err());
    }

    const MANIFEST: &str = r#"
[[artifact]]
name = "gemm_64"
hlo = "gemm_64.hlo.txt"
inputs = ["f32[64,64]", "f32[64,64]"]
outputs = ["f32[64,64]"]
golden_in = ["golden/gemm_64.in0.bin", "golden/gemm_64.in1.bin"]
golden_out = ["golden/gemm_64.out0.bin"]
"#;

    #[test]
    fn manifest_parses() {
        let r = Registry::from_manifest_text(Path::new("/tmp/a"), MANIFEST).unwrap();
        let s = r.spec("gemm_64").unwrap();
        assert_eq!(s.inputs.len(), 2);
        assert_eq!(s.hlo_path, Path::new("/tmp/a/gemm_64.hlo.txt"));
        assert!(r.spec("nope").is_err());
    }

    #[test]
    fn manifest_count_mismatch_rejected() {
        let bad = MANIFEST.replace(
            "golden_in = [\"golden/gemm_64.in0.bin\", \"golden/gemm_64.in1.bin\"]",
            "golden_in = [\"golden/gemm_64.in0.bin\"]",
        );
        assert!(Registry::from_manifest_text(Path::new("/tmp"), &bad).is_err());
    }

    #[test]
    fn empty_manifest_rejected() {
        assert!(Registry::from_manifest_text(Path::new("/tmp"), "# empty\n").is_err());
    }
}
