//! Runtime: load and execute the AOT-compiled JAX/Pallas artifacts via the
//! PJRT CPU client (`xla` crate).
//!
//! Python lowers every L1/L2 entry point to HLO **text** once (`make
//! artifacts`); this module is the only bridge between the Rust coordinator
//! and those numerics. Nothing here imports or spawns Python — the binary
//! is self-contained after artifacts are built.
//!
//! * [`Tensor`] — host-side f32 tensor (all artifact I/O is f32 by
//!   construction, see python/compile/aot.py).
//! * [`Engine`] — PJRT client + HLO-text loader.
//! * [`Registry`] — artifact manifest (`artifacts/manifest.toml`), shape
//!   specs and golden files.
//! * [`Runtime`] — engine + registry + executable cache; the facade the
//!   coordinator uses.

mod engine;
mod registry;
mod tensor;

pub use engine::{Engine, Executable};
pub use registry::{ArtifactSpec, DType, Registry, ShapeSpec};
pub use tensor::Tensor;

use crate::Result;
use std::collections::HashMap;
use std::sync::Arc;

/// Engine + registry + executable cache (one compile per artifact).
pub struct Runtime {
    engine: Engine,
    registry: Registry,
    cache: std::sync::Mutex<HashMap<String, Arc<Executable>>>,
}

impl Runtime {
    /// Open the default artifacts directory (see [`crate::artifacts_dir`]).
    pub fn open_default() -> Result<Self> {
        Self::open(&crate::artifacts_dir())
    }

    pub fn open(dir: &std::path::Path) -> Result<Self> {
        Ok(Runtime {
            engine: Engine::cpu()?,
            registry: Registry::open(dir)?,
            cache: std::sync::Mutex::new(HashMap::new()),
        })
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Fetch (compiling and caching on first use) an executable by name.
    pub fn executable(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.registry.spec(name)?;
        let exe = Arc::new(self.engine.load(spec)?);
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Compile-and-run convenience: `run("gemm_64", &[x, w])`.
    pub fn run(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.executable(name)?.run(inputs)
    }

    /// Names of all artifacts in the manifest.
    pub fn artifact_names(&self) -> Vec<String> {
        self.registry.names()
    }
}
