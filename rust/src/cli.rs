//! Command-line interface (hand-rolled — no clap in the offline image).
//!
//! Subcommands:
//! * `simulate` — compile + map + co-simulate a workload on a fabric.
//! * `dse`      — NoC topology design-space exploration.
//! * `dram`     — DRAM/PIM subsystem study (E3 rows).
//! * `run`      — execute an AOT artifact functionally and verify golden.
//! * `serve`    — batched-inference demo over an artifact.
//! * `report`   — environment + artifact inventory.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Context};

use crate::accel::Precision;
use crate::compiler::mapper::{map_graph, MapStrategy};
use crate::compiler::lowering::lower;
use crate::config::{FabricConfig, WorkloadConfig};
use crate::coordinator::{cosim, BatchServer};
use crate::dram::{DramKind, DramSim, DramTiming, PimCommand, Request};
use crate::dse::{explore, ExploreConfig, ExploreMethod};
use crate::fabric::Fabric;
use crate::runtime::Runtime;
use crate::workloads;
use crate::Result;

/// Parsed arguments: positional subcommand + `--key value` flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    pub flags: HashMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.iter();
        out.command = it.next().cloned().unwrap_or_else(|| "help".into());
        while let Some(a) = it.next() {
            let Some(key) = a.strip_prefix("--") else {
                bail!("expected --flag, got {a:?}");
            };
            let val = it.next().ok_or_else(|| anyhow!("--{key} needs a value"))?;
            out.flags.insert(key.to_string(), val.clone());
        }
        Ok(out)
    }

    pub fn get<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.flags.get(key).map(String::as_str).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?}")),
        }
    }
}

pub const HELP: &str = "\
archytas — post-CMOS accelerator fabric: simulation, compilation, DSE

USAGE: archytas <command> [--flag value]...

COMMANDS:
  simulate  --fabric <toml-path|default> --model <vit_tiny|mlp|cnn_edge>
            --precision <f32|int8|analog> --strategy <greedy|rr|ilp>
  dse       --nodes <n> --method <exhaustive|milp|smt|sim> --max-area <mm2>
  dram      --kind <ddr4|lpddr4|hbm2> --mode <stream|random|pim> --mb <n>
  run       --artifact <name> [--dir <artifacts-dir>]
  serve     --artifact <mlp_digital|mlp_npu_int8> --clients <n> --requests <n>
  report    [--dir <artifacts-dir>]
";

/// Execute a parsed command; returns the text report.
pub fn dispatch(args: &Args) -> Result<String> {
    match args.command.as_str() {
        "simulate" => cmd_simulate(args),
        "dse" => cmd_dse(args),
        "dram" => cmd_dram(args),
        "run" => cmd_run(args),
        "serve" => cmd_serve(args),
        "report" => cmd_report(args),
        "help" | "--help" | "-h" => Ok(HELP.to_string()),
        other => bail!("unknown command {other:?}\n{HELP}"),
    }
}

fn load_fabric(args: &Args) -> Result<Fabric> {
    let path = args.get("fabric", "default");
    let cfg = if path == "default" {
        FabricConfig::default()
    } else {
        FabricConfig::from_toml(
            &std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?,
        )?
    };
    Fabric::build(cfg)
}

fn build_workload(model: &str) -> Result<crate::ir::Graph> {
    match model {
        "vit_tiny" => workloads::vit(&workloads::VitParams::default(), 0),
        "mlp" => workloads::mlp(8, 256, &[128, 64], 10, 0),
        "cnn_edge" => workloads::cnn_edge(2, 0),
        other => bail!("unknown model {other:?}"),
    }
}

fn cmd_simulate(args: &Args) -> Result<String> {
    let fabric = load_fabric(args)?;
    let model = args.get("model", "vit_tiny");
    let g = build_workload(model)?;
    let wl = WorkloadConfig { model: model.into(), batch: 4, precision: args.get("precision", "int8").into() };
    let precision = match wl.precision.as_str() {
        "f32" => Precision::F32,
        "int8" => Precision::Int8,
        "analog" => Precision::Analog,
        other => bail!("unknown precision {other:?}"),
    };
    let strategy = match args.get("strategy", "greedy") {
        "greedy" => MapStrategy::Greedy,
        "rr" => MapStrategy::RoundRobin,
        "ilp" => MapStrategy::Ilp,
        other => bail!("unknown strategy {other:?}"),
    };
    let mapping = map_graph(&g, &fabric, strategy, precision)?;
    let prog = lower(&g, &fabric, &mapping)?;
    let rep = cosim(&fabric, &prog)?;
    let freq = fabric.cfg.freq_ghz;
    let mut out = String::new();
    out += &format!(
        "simulate: model={model} precision={} strategy={:?} fabric={} ({} tiles, {:.1} mm²)\n",
        wl.precision,
        strategy,
        fabric.cfg.name,
        fabric.tile_count(),
        fabric.total_area().mm2,
    );
    out += &format!(
        "  makespan {:>10} cyc  ({:.3} us @ {freq} GHz)\n",
        rep.cycles,
        rep.cycles as f64 / (freq * 1e9) * 1e6
    );
    out += &format!("  energy   {:>10.1} nJ\n", rep.metrics.total_energy_pj() / 1e3);
    out += &format!("  transfers {:>9} cyc ({} steps, {} exec)\n",
        rep.transfer_cycles, prog.steps.len(), rep.exec_steps);
    out += &format!("  mean tile utilization {:.1}%\n", rep.mean_utilization() * 100.0);
    for (cat, pj) in rep.metrics.breakdown() {
        out += &format!("    {cat:<8} {:>12.1} pJ\n", pj);
    }
    Ok(out)
}

fn cmd_dse(args: &Args) -> Result<String> {
    let cfg = ExploreConfig {
        min_nodes: args.get_usize("nodes", 16)?,
        max_area: args.get_f64("max-area", 10.0)?,
        ..Default::default()
    };
    let method = match args.get("method", "exhaustive") {
        "exhaustive" => ExploreMethod::Exhaustive,
        "milp" => ExploreMethod::Milp,
        "smt" => ExploreMethod::Smt,
        "sim" => ExploreMethod::IterativeSim,
        other => bail!("unknown method {other:?}"),
    };
    let r = explore(&cfg, method)?;
    let mut out = format!(
        "dse: nodes>={} method={method:?} solver_evals={} sim_evals={}\n",
        cfg.min_nodes, r.solver_evals, r.sim_evals
    );
    out += &format!(
        "  {:<12} {:>8} {:>10} {:>8} {:>10} {:>6} {:>9}\n",
        "topology", "avg-hops", "est-lat", "area", "pJ/KiB", "radix", "sim-lat"
    );
    for (i, c) in r.candidates.iter().enumerate() {
        let marks = format!(
            "{}{}",
            if i == r.best { " <= best" } else { "" },
            if r.front.contains(&i) { " *pareto" } else { "" }
        );
        out += &format!(
            "  {:<12} {:>8.2} {:>10.1} {:>8.2} {:>10.0} {:>6} {:>9}{}\n",
            c.name,
            c.avg_hops,
            c.est_latency,
            c.area,
            c.energy_per_kib,
            c.max_radix,
            c.sim_latency.map_or("-".into(), |l| format!("{l:.1}")),
            marks
        );
    }
    Ok(out)
}

fn cmd_dram(args: &Args) -> Result<String> {
    let kind = match args.get("kind", "ddr4") {
        "ddr4" => DramKind::Ddr4_2400,
        "lpddr4" => DramKind::Lpddr4_3200,
        "hbm2" => DramKind::Hbm2,
        other => bail!("unknown dram kind {other:?}"),
    };
    let mb = args.get_usize("mb", 1)?;
    let bytes = mb * 1024 * 1024;
    let t = DramTiming::new(kind);
    let mut sim = DramSim::new(t);
    let mode = args.get("mode", "stream");
    match mode {
        "stream" => {
            for i in 0..(bytes / t.row_bytes) {
                sim.enqueue(Request::read((i * t.row_bytes) as u64, t.row_bytes));
            }
        }
        "random" => {
            let mut rng = crate::sim::Rng::new(1);
            for _ in 0..(bytes / t.burst_bytes).min(16384) {
                let addr = (rng.below(1 << 26)) as u64 & !63;
                sim.enqueue(Request::read(addr, t.burst_bytes));
            }
        }
        "pim" => {
            let macs = (bytes / 4) as u64 / t.banks as u64;
            for b in 0..t.banks {
                sim.enqueue(Request::pim(
                    (b * t.row_bytes) as u64,
                    PimCommand::BankMac { macs },
                ));
            }
        }
        other => bail!("unknown mode {other:?}"),
    }
    let st = sim.run_to_drain();
    Ok(format!(
        "dram: kind={kind:?} mode={mode} footprint={mb} MiB\n\
         \x20 cycles {:>12}  ({:.3} us)\n\
         \x20 bandwidth {:>9.2} GB/s (peak {:.2})\n\
         \x20 energy {:>12.1} nJ  row-hit {:.1}%  acts {}  pim-macs {}\n\
         \x20 avg latency {:>7.1} cyc\n",
        st.cycles,
        st.cycles as f64 / (t.freq_ghz * 1e9) * 1e6,
        st.bandwidth_gbs(&t),
        t.peak_bandwidth_gbs(),
        st.metrics.total_energy_pj() / 1e3,
        st.row_hit_rate() * 100.0,
        st.activations,
        st.pim_macs,
        st.avg_latency,
    ))
}

fn cmd_run(args: &Args) -> Result<String> {
    let dir = args.get("dir", "");
    let rt = if dir.is_empty() {
        Runtime::open_default()?
    } else {
        Runtime::open(std::path::Path::new(dir))?
    };
    let name = args.get("artifact", "gemm_64");
    let inputs = rt.registry().golden_inputs(name)?;
    let want = rt.registry().golden_outputs(name)?;
    let t0 = std::time::Instant::now();
    let got = rt.run(name, &inputs)?;
    let dt = t0.elapsed();
    let mut worst = 0.0f32;
    for (g, w) in got.iter().zip(&want) {
        worst = worst.max(g.max_abs_diff(w)?);
    }
    Ok(format!(
        "run: artifact={name} exec={:.3} ms outputs={} max|Δ| vs golden = {worst:.2e}  [{}]\n",
        dt.as_secs_f64() * 1e3,
        got.len(),
        if worst < 1e-3 { "OK" } else { "MISMATCH" }
    ))
}

fn cmd_serve(args: &Args) -> Result<String> {
    let rt = Runtime::open_default()?;
    let name = args.get("artifact", "mlp_digital");
    let spec = rt.registry().spec(name)?;
    anyhow::ensure!(
        spec.inputs.len() == 1 && spec.inputs[0].dims.len() == 2,
        "serve needs a single 2-D-input artifact (batch, features)"
    );
    let batch = spec.inputs[0].dims[0];
    let feat = spec.inputs[0].dims[1];
    let out_cols = spec.outputs[0].dims[1];
    let clients = args.get_usize("clients", 4)?;
    let per = args.get_usize("requests", 16)?;
    let exe = rt.executable(name)?;
    let server = BatchServer::new(feat, out_cols, batch);
    let t0 = std::time::Instant::now();
    let (stats, _) = crate::coordinator::serve::drive_server(
        &server,
        clients,
        per,
        move |c, i| {
            let mut rng = crate::sim::Rng::new((c * 1000 + i) as u64);
            (0..feat).map(|_| rng.normal() as f32).collect()
        },
        move |input| Ok(exe.run(std::slice::from_ref(input))?.remove(0)),
    )?;
    let wall = t0.elapsed().as_secs_f64();
    Ok(format!(
        "serve: artifact={name} clients={clients} requests={}\n\
         \x20 batches {}  mean batch {:.2}/{batch}\n\
         \x20 p50 {:.0} us  p99 {:.0} us  throughput {:.0} req/s\n",
        stats.requests,
        stats.batches,
        stats.mean_batch(),
        stats.p50_latency_us(),
        stats.p99_latency_us(),
        stats.throughput_rps(wall),
    ))
}

fn cmd_report(args: &Args) -> Result<String> {
    let dir = args.get("dir", "");
    let dir = if dir.is_empty() { crate::artifacts_dir() } else { dir.into() };
    let mut out = format!("archytas report\n  artifacts dir: {dir:?}\n");
    match Runtime::open(&dir) {
        Ok(rt) => {
            out += &format!("  artifacts: {}\n", rt.artifact_names().len());
            for n in rt.artifact_names() {
                let s = rt.registry().spec(&n).unwrap();
                out += &format!(
                    "    {:<22} in={:?} out={:?}\n",
                    n,
                    s.inputs.iter().map(|i| i.dims.clone()).collect::<Vec<_>>(),
                    s.outputs.iter().map(|o| o.dims.clone()).collect::<Vec<_>>()
                );
            }
        }
        Err(e) => out += &format!("  (no artifacts: {e})\n"),
    }
    out += &format!("  default fabric: {:?}\n", FabricConfig::default());
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_flags() {
        let a = Args::parse(&argv(&["dse", "--nodes", "32", "--method", "milp"])).unwrap();
        assert_eq!(a.command, "dse");
        assert_eq!(a.get("method", ""), "milp");
        assert_eq!(a.get_usize("nodes", 0).unwrap(), 32);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
    }

    #[test]
    fn parse_rejects_bad_shapes() {
        assert!(Args::parse(&argv(&["x", "stray"])).is_err());
        assert!(Args::parse(&argv(&["x", "--flag"])).is_err());
        let a = Args::parse(&argv(&["x", "--n", "abc"])).unwrap();
        assert!(a.get_usize("n", 0).is_err());
    }

    #[test]
    fn help_and_unknown() {
        let h = dispatch(&Args::parse(&argv(&["help"])).unwrap()).unwrap();
        assert!(h.contains("simulate"));
        assert!(dispatch(&Args::parse(&argv(&["frobnicate"])).unwrap()).is_err());
    }

    #[test]
    fn simulate_smoke() {
        let a = Args::parse(&argv(&["simulate", "--model", "mlp", "--precision", "int8"]))
            .unwrap();
        let out = dispatch(&a).unwrap();
        assert!(out.contains("makespan"), "{out}");
        assert!(out.contains("utilization"));
    }

    #[test]
    fn dse_smoke_all_methods() {
        for m in ["exhaustive", "milp", "smt"] {
            let a = Args::parse(&argv(&["dse", "--nodes", "12", "--method", m])).unwrap();
            let out = dispatch(&a).unwrap();
            assert!(out.contains("<= best"), "{m}: {out}");
        }
    }

    #[test]
    fn dram_smoke_modes() {
        for mode in ["stream", "random", "pim"] {
            let a = Args::parse(&argv(&["dram", "--mode", mode, "--mb", "1"])).unwrap();
            let out = dispatch(&a).unwrap();
            assert!(out.contains("bandwidth"), "{mode}: {out}");
        }
    }
}
