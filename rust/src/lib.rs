//! # ARCHYTAS — architecture, simulation and software stack for post-CMOS
//! AI accelerators
//!
//! Reproduction of the ARCHYTAS project paper (ISVLSI 2025): a scalable
//! heterogeneous compute fabric (tiled NoC with post-CMOS accelerator
//! compute units), the simulation infrastructure to prototype it (flit-level
//! NoC, JEDEC-timing DRAM with Processing-In-Memory extensions, analytic
//! accelerator models), the software stack to program it (NN graph IR,
//! sparsification / quantization / TAFFO-style precision-tuning compiler
//! passes, a layer-to-CU mapper) and MILP/SMT design-space exploration —
//! with the numeric hot path AOT-compiled from JAX/Pallas and executed via
//! PJRT (see [`runtime`]).
//!
//! Layer map (DESIGN.md §3):
//! * L3 (this crate): coordination, simulation, compilation, DSE.
//! * L2 (`python/compile/model.py`): JAX model variants, lowered once.
//! * L1 (`python/compile/kernels/`): Pallas kernels (crossbar / int8 /
//!   block-sparse), verified against pure-jnp oracles.

pub mod accel;
pub mod cli;
pub mod compiler;
pub mod config;
pub mod coordinator;
pub mod dram;
pub mod dse;
pub mod fabric;
pub mod ir;
pub mod metrics;
pub mod noc;
pub mod runtime;
pub mod sim;
pub mod testutil;
pub mod workloads;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Returns the repository root (honours `ARCHYTAS_ROOT`, falls back to the
/// cargo manifest dir so tests and examples find `artifacts/`).
pub fn repo_root() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("ARCHYTAS_ROOT") {
        return p.into();
    }
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

/// Default artifacts directory (`<root>/artifacts`).
pub fn artifacts_dir() -> std::path::PathBuf {
    repo_root().join("artifacts")
}
