//! Network-on-Chip simulator (paper Sec. III).
//!
//! The ARCHYTAS Scalable Compute Fabric couples its heterogeneous Compute
//! Units through a NoC; this module provides the flit-level,
//! credit-flow-controlled wormhole simulator used for (a) the fabric
//! co-simulation (`coordinator`), (b) the NoC scaling study (E2) and
//! (c) the topology design-space exploration (E4, `dse`).
//!
//! Link parameters default to the FlooNoC figures the paper builds on
//! (645 Gb/s per link, 0.15 pJ/bit/hop — Fischer et al. [18]).
//!
//! * [`Topology`] — node/link graph with mesh/torus/ring/star/fat-tree
//!   constructors plus arbitrary (low-radix) custom graphs.
//! * [`routing`] — dimension-order XY (deadlock-free on mesh/torus) and
//!   table-based shortest-path next-hop functions.
//! * [`NocSim`] — cycle-stepped wormhole router network with virtual
//!   channels and credit flow control, built on the flat event-wheel hot
//!   loop; steps shard-parallel at `NocParams::threads > 1` with
//!   bit-identical reports (see `sim.rs` module docs for the buffer
//!   layout and the determinism contract).
//! * [`refsim`] — the retained pre-rewrite implementation, used as the
//!   differential-testing golden reference and perf baseline.
//! * [`traffic`] — uniform / hotspot / transpose / neighbour generators.
//! * [`floorplan`] — approximate placement + Manhattan link lengths for
//!   the cost model the DSE toolchain uses.

mod floorplan;
mod router;
mod sim;
mod topology;
pub mod refsim;
pub mod routing;
pub mod traffic;

pub use floorplan::{Floorplan, LinkCost};
pub use router::{Flit, FlitKind};
pub use sim::{NocParams, NocSim, PacketStats, SimReport};
pub use topology::{NodeId, Topology, TopologyKind};
