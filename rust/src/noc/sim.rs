//! Cycle-stepped wormhole NoC simulation with virtual channels and credit
//! flow control.
//!
//! Model (one clock domain, all routers step synchronously):
//! * A packet is flitized at injection (`ceil(bytes / flit_bytes)` flits,
//!   head…tail) and assigned a VC (`packet_id % vcs`).
//! * Each router has one input port per incident link plus a local
//!   injection port, and one output port per link plus a local ejection
//!   port. Per cycle each input port sends at most one flit and each
//!   output port accepts at most one flit (crossbar constraint).
//! * A head flit arbitrates (round-robin) for its routed output port and
//!   allocates (port, vc) until its tail passes — wormhole switching.
//! * Forwarding consumes one downstream credit; credits return to the
//!   upstream router one cycle after the downstream buffer drains.
//! * A forwarded flit arrives `router_latency` cycles later at the next
//!   router (pipeline depth), 1 flit/cycle/link throughput.
//!
//! Determinism: routers and ports are iterated in fixed order, all moves
//! are double-buffered within a cycle, and all randomness lives in the
//! traffic generators (seeded).

use std::collections::VecDeque;

use super::router::{Flit, FlitKind, RouterState};
use super::routing::RouteTable;
use super::topology::{NodeId, Topology};
use crate::metrics::{Category, Metrics};
use crate::sim::Cycle;

/// Microarchitectural NoC parameters (config defaults are FlooNoC-like).
#[derive(Debug, Clone, Copy)]
pub struct NocParams {
    pub vcs: usize,
    /// Input buffer depth per VC, in flits.
    pub buf_flits: usize,
    pub flit_bytes: usize,
    /// Router pipeline depth (cycles per hop).
    pub router_latency: Cycle,
    /// Link + router energy per bit per hop (pJ).
    pub hop_energy_pj_per_bit: f64,
}

impl Default for NocParams {
    fn default() -> Self {
        NocParams {
            vcs: 2,
            buf_flits: 4,
            flit_bytes: 32,
            router_latency: 3,
            hop_energy_pj_per_bit: 0.15,
        }
    }
}

impl NocParams {
    pub fn from_config(cfg: &crate::config::NocConfig) -> Self {
        NocParams {
            vcs: cfg.vcs,
            buf_flits: 4,
            flit_bytes: cfg.flit_bytes,
            router_latency: cfg.router_latency_cycles,
            hop_energy_pj_per_bit: cfg.hop_energy_pj_per_bit,
        }
    }
}

/// Lifetime record of one packet.
#[derive(Debug, Clone, Copy)]
pub struct PacketStats {
    pub src: NodeId,
    pub dst: NodeId,
    pub flits: usize,
    pub injected_at: Cycle,
    /// Cycle the tail flit was ejected (None while in flight).
    pub ejected_at: Option<Cycle>,
    pub hops: usize,
}

/// Aggregate simulation report (one bench-table row).
#[derive(Debug, Clone)]
pub struct SimReport {
    pub cycles: Cycle,
    pub delivered: usize,
    pub in_flight: usize,
    pub avg_latency: f64,
    pub p99_latency: f64,
    pub flit_hops: u64,
    /// Delivered flits per node per cycle.
    pub throughput: f64,
    pub metrics: Metrics,
}

struct Arrival {
    at: Cycle,
    node: NodeId,
    port: usize,
    flit: Flit,
}

struct CreditReturn {
    at: Cycle,
    node: NodeId,
    out_port: usize,
    vc: usize,
}

/// The simulator.
pub struct NocSim {
    topo: Topology,
    routes: RouteTable,
    params: NocParams,
    routers: Vec<RouterState>,
    /// Pending packet flits waiting at each source (unbounded source
    /// queue feeding the local injection port).
    inject_q: Vec<VecDeque<Flit>>,
    arrivals: Vec<Arrival>,
    credit_returns: Vec<CreditReturn>,
    packets: Vec<PacketStats>,
    now: Cycle,
    flit_hops: u64,
    delivered: usize,
}

impl NocSim {
    pub fn new(topo: Topology, params: NocParams) -> Self {
        let routes = RouteTable::build(&topo);
        let routers = (0..topo.nodes())
            .map(|n| {
                let deg = topo.degree(n);
                RouterState::new(deg + 1, deg + 1, params.vcs, params.buf_flits)
            })
            .collect();
        let inject_q = (0..topo.nodes()).map(|_| VecDeque::new()).collect();
        NocSim {
            topo,
            routes,
            params,
            routers,
            inject_q,
            arrivals: Vec::new(),
            credit_returns: Vec::new(),
            packets: Vec::new(),
            now: 0,
            flit_hops: 0,
            delivered: 0,
        }
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    pub fn now(&self) -> Cycle {
        self.now
    }

    pub fn packets(&self) -> &[PacketStats] {
        &self.packets
    }

    /// Queue a packet for injection at the current cycle. Returns its id.
    pub fn inject(&mut self, src: NodeId, dst: NodeId, bytes: usize) -> usize {
        assert!(src < self.topo.nodes() && dst < self.topo.nodes());
        assert_ne!(src, dst, "self-traffic is not modelled");
        let id = self.packets.len();
        let nflits = bytes.div_ceil(self.params.flit_bytes).max(1);
        let vc = id % self.params.vcs;
        for i in 0..nflits {
            let kind = if i + 1 == nflits {
                FlitKind::Tail
            } else if i == 0 {
                FlitKind::Head
            } else {
                FlitKind::Body
            };
            self.inject_q[src].push_back(Flit {
                packet: id,
                kind,
                is_head: i == 0,
                dst,
                vc,
            });
        }
        self.packets.push(PacketStats {
            src,
            dst,
            flits: nflits,
            injected_at: self.now,
            ejected_at: None,
            hops: self.routes.route_len(src, dst),
        });
        id
    }

    /// Input-port index at `to` for the link arriving from `from`.
    fn in_port(&self, to: NodeId, from: NodeId) -> usize {
        self.topo
            .neighbors(to)
            .iter()
            .position(|&(v, _)| v == from)
            .expect("link endpoints inconsistent")
    }

    /// Advance one cycle.
    pub fn step(&mut self) {
        let nodes = self.topo.nodes();
        let vcs = self.params.vcs;

        // 1. Local injection: move flits from source queues into the local
        //    input port's VC buffer while space remains.
        for n in 0..nodes {
            let local = self.topo.degree(n); // local input port index
            while let Some(&flit) = self.inject_q[n].front() {
                let buf = &mut self.routers[n].in_buf[local][flit.vc];
                if buf.len() >= self.params.buf_flits {
                    break;
                }
                buf.push_back(self.inject_q[n].pop_front().unwrap());
            }
        }

        // 2. Switch allocation + traversal, double-buffered.
        let mut new_arrivals: Vec<Arrival> = Vec::new();
        let mut new_credits: Vec<CreditReturn> = Vec::new();
        for n in 0..nodes {
            let deg = self.topo.degree(n);
            let ports_in = deg + 1;
            let mut input_busy = vec![false; ports_in];
            // Output ports in fixed order: links first, then ejection.
            for p_out in 0..=deg {
                // 2a. VC allocation: head flits claim a free (p_out, vc).
                for p_in in 0..ports_in {
                    for vc in 0..vcs {
                        let Some(&flit) = self.routers[n].in_buf[p_in][vc].front() else {
                            continue;
                        };
                        if !flit.is_head {
                            continue; // body/tail follow the allocation
                        }
                        let want = self.route_port(n, flit.dst, deg);
                        if want != p_out {
                            continue;
                        }
                        if self.routers[n].out_owner[p_out][vc].is_none() {
                            self.routers[n].out_owner[p_out][vc] = Some((p_in, vc));
                        }
                    }
                }
                // 2b. Switch traversal: round-robin over VCs that own this
                //     output; forward at most one flit per output port.
                let rr0 = self.routers[n].rr[p_out];
                for k in 0..vcs {
                    let vc = (rr0 + k) % vcs;
                    let Some((p_in, in_vc)) = self.routers[n].out_owner[p_out][vc] else {
                        continue;
                    };
                    if input_busy[p_in] {
                        continue;
                    }
                    let Some(&flit) = self.routers[n].in_buf[p_in][in_vc].front() else {
                        continue;
                    };
                    // Only flits of the owning packet may use the slot.
                    let owner_ok = {
                        // The queue is FIFO per (port, vc); the owning
                        // packet's flits are contiguous (wormhole), so the
                        // front flit routed to this port belongs to it.
                        let want = if flit.dst == n {
                            deg
                        } else {
                            self.route_port(n, flit.dst, deg)
                        };
                        want == p_out
                    };
                    if !owner_ok {
                        continue;
                    }
                    let is_ejection = p_out == deg;
                    if !is_ejection && self.routers[n].credits[p_out][vc] == 0 {
                        continue;
                    }
                    // Commit the move.
                    let flit = self.routers[n].in_buf[p_in][in_vc].pop_front().unwrap();
                    input_busy[p_in] = true;
                    self.routers[n].rr[p_out] = (vc + 1) % vcs;
                    if flit.kind == FlitKind::Tail {
                        self.routers[n].out_owner[p_out][vc] = None;
                    }
                    // Return a credit upstream for the buffer we freed
                    // (unless it was the local injection queue, which is
                    // backpressured directly).
                    if p_in < deg {
                        let (up, _) = self.topo.neighbors(n)[p_in];
                        // Credits are indexed by the upstream router's
                        // output port towards us == position of n in the
                        // upstream neighbor list.
                        let up_out_port = self.in_port(up, n);
                        new_credits.push(CreditReturn {
                            at: self.now + 1,
                            node: up,
                            out_port: up_out_port,
                            vc: in_vc,
                        });
                    }
                    if is_ejection {
                        // Ejected at the local sink.
                        if flit.kind == FlitKind::Tail {
                            let p = &mut self.packets[flit.packet];
                            p.ejected_at = Some(self.now + 1);
                            self.delivered += 1;
                        }
                    } else {
                        let (next, _) = self.topo.neighbors(n)[p_out];
                        let dest_port = self.in_port(next, n);
                        self.routers[n].credits[p_out][vc] -= 1;
                        self.flit_hops += 1;
                        new_arrivals.push(Arrival {
                            at: self.now + self.params.router_latency,
                            node: next,
                            port: dest_port,
                            flit,
                        });
                    }
                }
            }
        }

        // 3. Apply arrivals whose time has come (including older ones).
        self.arrivals.extend(new_arrivals);
        self.credit_returns.extend(new_credits);
        let now_next = self.now + 1;
        let mut rest = Vec::with_capacity(self.arrivals.len());
        for a in self.arrivals.drain(..) {
            if a.at <= now_next {
                self.routers[a.node].in_buf[a.port][a.flit.vc].push_back(a.flit);
            } else {
                rest.push(a);
            }
        }
        self.arrivals = rest;
        let mut rest = Vec::with_capacity(self.credit_returns.len());
        for c in self.credit_returns.drain(..) {
            if c.at <= now_next {
                self.routers[c.node].credits[c.out_port][c.vc] += 1;
            } else {
                rest.push(c);
            }
        }
        self.credit_returns = rest;

        self.now = now_next;
    }

    /// Output port at `n` towards `dst` (deg = ejection if dst == n).
    fn route_port(&self, n: NodeId, dst: NodeId, deg: usize) -> usize {
        if dst == n {
            return deg;
        }
        let next = self.routes.next_hop(n, dst);
        self.topo
            .neighbors(n)
            .iter()
            .position(|&(v, _)| v == next)
            .expect("route table returned non-neighbor")
    }

    /// True when no flits remain anywhere.
    pub fn drained(&self) -> bool {
        self.inject_q.iter().all(VecDeque::is_empty)
            && self.arrivals.is_empty()
            && self.routers.iter().all(|r| r.occupancy() == 0)
    }

    /// Run until drained or `max_cycles`, then report.
    pub fn run_to_drain(&mut self, max_cycles: Cycle) -> SimReport {
        while !self.drained() && self.now < max_cycles {
            self.step();
        }
        self.report()
    }

    /// Run exactly `cycles` more cycles.
    pub fn run_for(&mut self, cycles: Cycle) {
        for _ in 0..cycles {
            self.step();
        }
    }

    pub fn report(&self) -> SimReport {
        let mut lats: Vec<u64> = self
            .packets
            .iter()
            .filter_map(|p| p.ejected_at.map(|e| e - p.injected_at))
            .collect();
        lats.sort_unstable();
        let avg = if lats.is_empty() {
            0.0
        } else {
            lats.iter().sum::<u64>() as f64 / lats.len() as f64
        };
        let p99 = if lats.is_empty() {
            0.0
        } else {
            lats[(lats.len() - 1).min(lats.len() * 99 / 100)] as f64
        };
        let mut metrics = Metrics::new();
        metrics.cycles = self.now;
        metrics.bytes_moved = self.flit_hops * self.params.flit_bytes as u64;
        metrics.add_energy(
            Category::Noc,
            self.flit_hops as f64 * self.params.flit_bytes as f64 * 8.0
                * self.params.hop_energy_pj_per_bit,
        );
        let delivered_flits: usize = self
            .packets
            .iter()
            .filter(|p| p.ejected_at.is_some())
            .map(|p| p.flits)
            .sum();
        SimReport {
            cycles: self.now,
            delivered: self.delivered,
            in_flight: self.packets.len() - self.delivered,
            avg_latency: avg,
            p99_latency: p99,
            flit_hops: self.flit_hops,
            throughput: if self.now == 0 {
                0.0
            } else {
                delivered_flits as f64 / self.now as f64 / self.topo.nodes() as f64
            },
            metrics,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mesh_sim(w: usize, h: usize) -> NocSim {
        NocSim::new(Topology::mesh(w, h).unwrap(), NocParams::default())
    }

    #[test]
    fn single_packet_latency_matches_analytic() {
        let mut sim = mesh_sim(4, 4);
        // 0 -> 15: 6 hops; 64B = 2 flits.
        sim.inject(0, 15, 64);
        let rep = sim.run_to_drain(10_000);
        assert_eq!(rep.delivered, 1);
        let lat = sim.packets()[0].ejected_at.unwrap() - sim.packets()[0].injected_at;
        // serialization (2 flits) + hops * router_latency + inject/eject.
        let expect_min = 6 * 3; // hops * pipeline
        assert!(lat >= expect_min as u64, "lat {lat}");
        assert!(lat <= expect_min as u64 + 10, "lat {lat}");
    }

    #[test]
    fn all_packets_delivered_exactly_once() {
        let mut sim = mesh_sim(4, 4);
        let mut rng = crate::sim::Rng::new(7);
        for _ in 0..200 {
            let s = rng.below(16);
            let mut d = rng.below(16);
            while d == s {
                d = rng.below(16);
            }
            sim.inject(s, d, 32 + rng.below(97));
        }
        let rep = sim.run_to_drain(100_000);
        assert!(sim.drained(), "network drained");
        assert_eq!(rep.delivered, 200);
        assert_eq!(rep.in_flight, 0);
        assert!(sim.packets().iter().all(|p| p.ejected_at.is_some()));
    }

    #[test]
    fn torus_delivers_under_load() {
        let mut sim = NocSim::new(Topology::torus(4, 4).unwrap(), NocParams::default());
        let mut rng = crate::sim::Rng::new(3);
        for _ in 0..100 {
            let s = rng.below(16);
            let d = (s + 1 + rng.below(15)) % 16;
            sim.inject(s, d, 64);
        }
        let rep = sim.run_to_drain(100_000);
        assert_eq!(rep.delivered, 100);
    }

    #[test]
    fn hotspot_slower_than_uniform() {
        // All-to-one congests; same offered load spread uniformly drains
        // faster. (The paper's E2 saturation shape, in miniature.)
        let mut uni = mesh_sim(4, 4);
        let mut hot = mesh_sim(4, 4);
        let mut rng = crate::sim::Rng::new(11);
        for i in 0..60 {
            let s = (i * 5 + 1) % 16;
            let mut d = rng.below(16);
            while d == s {
                d = rng.below(16);
            }
            if s != 0 {
                hot.inject(s, 0, 128);
            }
            uni.inject(s, d, 128);
        }
        let ru = uni.run_to_drain(100_000);
        let rh = hot.run_to_drain(100_000);
        assert!(rh.cycles > ru.cycles, "hotspot {} vs uniform {}", rh.cycles, ru.cycles);
    }

    #[test]
    fn energy_scales_with_hops() {
        let mut near = mesh_sim(4, 4);
        near.inject(0, 1, 256);
        let rn = near.run_to_drain(10_000);
        let mut far = mesh_sim(4, 4);
        far.inject(0, 15, 256);
        let rf = far.run_to_drain(10_000);
        assert_eq!(rn.flit_hops * 6, rf.flit_hops); // 1 hop vs 6 hops
        let en = rn.metrics.total_energy_pj();
        let ef = rf.metrics.total_energy_pj();
        assert!((ef / en - 6.0).abs() < 1e-9);
    }

    #[test]
    fn flits_count_matches_bytes() {
        let mut sim = mesh_sim(2, 2);
        sim.inject(0, 1, 1); // 1 flit minimum
        sim.inject(0, 1, 32); // exactly 1
        sim.inject(0, 1, 33); // 2
        assert_eq!(sim.packets()[0].flits, 1);
        assert_eq!(sim.packets()[1].flits, 1);
        assert_eq!(sim.packets()[2].flits, 2);
    }

    #[test]
    #[should_panic(expected = "self-traffic")]
    fn rejects_self_traffic() {
        mesh_sim(2, 2).inject(1, 1, 32);
    }

    #[test]
    fn deterministic_replay() {
        let run = || {
            let mut sim = mesh_sim(4, 4);
            let mut rng = crate::sim::Rng::new(99);
            for _ in 0..150 {
                let s = rng.below(16);
                let mut d = rng.below(16);
                while d == s {
                    d = rng.below(16);
                }
                sim.inject(s, d, 64);
            }
            let r = sim.run_to_drain(100_000);
            (r.cycles, r.flit_hops, r.avg_latency.to_bits())
        };
        assert_eq!(run(), run());
    }
}
