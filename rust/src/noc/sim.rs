//! Cycle-stepped wormhole NoC simulation with virtual channels and credit
//! flow control.
//!
//! Model (one clock domain, all routers step synchronously):
//! * A packet is flitized at injection (`ceil(bytes / flit_bytes)` flits,
//!   head…tail) and assigned a VC (`packet_id % vcs`).
//! * Each router has one input port per incident link plus a local
//!   injection port, and one output port per link plus a local ejection
//!   port. Per cycle each input port sends at most one flit and each
//!   output port accepts at most one flit (crossbar constraint).
//! * A head flit arbitrates (round-robin) for its routed output port and
//!   allocates (port, vc) until its tail passes — wormhole switching.
//! * Forwarding consumes one downstream credit; credits return to the
//!   upstream router one cycle after the downstream buffer drains.
//! * A forwarded flit arrives `router_latency` cycles later at the next
//!   router (pipeline depth), 1 flit/cycle/link throughput.
//!
//! Determinism: routers and ports are iterated in fixed order, all moves
//! are double-buffered within a cycle, and all randomness lives in the
//! traffic generators (seeded).
//!
//! # Hot-loop layout (event-wheel rewrite)
//!
//! The cycle loop is flat and allocation-free in steady state:
//!
//! * **Buffers** — all (node, port, vc) input queues live in one
//!   contiguous [`FlitQueues`] arena. Queue ids are dense:
//!   `qbase[n] + port * vcs + vc`, with `qbase` the per-node prefix sum
//!   of `(degree + 1) * vcs`. Credits and output-owner state use the
//!   same indexing in flat arrays (`credits`, `owner`), and round-robin
//!   pointers use the analogous per-port prefix (`pbase[n] + port`).
//! * **Events** — in-flight flits and credit returns live in two
//!   [`EventWheel`] calendar queues instead of unsorted `Vec`s that were
//!   drained and reallocated every cycle. Push is O(1); the end-of-cycle
//!   drain hands back the due bucket's storage, which is recycled.
//! * **Routing** — the output port towards a destination is a single
//!   computed/table read ([`RouteTable::out_port`]), and the far-end
//!   input port of every link is a precomputed reverse-port lookup
//!   ([`Topology::reverse_port`]); the old code recomputed both with
//!   linear neighbor scans per flit per cycle.
//! * **Worklist** — per-node buffered-flit counts (`occ`) let the loop
//!   skip idle routers outright: an empty router with an empty source
//!   queue cannot allocate, traverse, or emit events, so skipping it is
//!   exactly behavior-preserving.
//!
//! # Parallel stepping — the determinism contract
//!
//! With [`NocParams::threads`] > 1 the per-node phases of a cycle run
//! shard-parallel on a persistent [`WorkerPool`] owned by the simulator.
//! The node range is partitioned once into contiguous shards (balanced
//! by queue count; [`NocSim::set_shards`] overrides the partition). The
//! flat-arena layout makes every per-node index range contiguous, so
//! each shard gets disjoint `&mut` views of the buffers
//! ([`FlitQueues::shards`]), credits, owners, round-robin pointers,
//! occupancy counts and source queues — a node phase touches no state
//! outside its shard. Determinism is a *contract*, not an accident:
//!
//! * **Shard-local writes only.** Inside the parallel phase a node may
//!   mutate arena state only within its own shard's range. Everything
//!   that crosses a shard boundary — flit arrivals, credit returns,
//!   ejection records, hop counts — is appended (in node order) to the
//!   shard's private [`ShardScratch`], never applied directly.
//! * **Order-merged side effects.** After the shards join, a sequential
//!   merge drains every scratch in global node order
//!   ([`EventWheel::push_all`]), replaying the exact push sequence the
//!   single-thread loop produces: the wheels' FIFO tie-break order,
//!   packet bookkeeping, `StreamingHist` latency samples and every
//!   [`SimReport`] bit are identical for every partition and thread
//!   count (tests/noc_golden.rs threads sweep,
//!   `prop_shard_partition_invariance`).
//! * **Position-keyed randomness.** The cycle loop draws no randomness
//!   today; if a future phase ever does (adaptive routing, fault
//!   injection), it must use [`crate::sim::CounterRng`] keyed by
//!   (cycle, node, draw index) so draw values depend on position, never
//!   on which thread ran first.
//!
//! The parallel path allocates nothing per cycle: shard views are carved
//! lazily ([`FlitQueues::shard_views`] walks `split_at_mut`) and each
//! context is handed to a worker as it is built. The default
//! `threads = 1` path builds a single whole-arena view with no per-step
//! allocation and is exactly the sequential simulator.
//!
//! Behavior is pinned by differential golden tests against
//! [`super::refsim::RefNocSim`], the retained pre-rewrite implementation:
//! on fixed seeds both produce bit-identical [`SimReport`]s (see
//! `tests/noc_golden.rs`).

use std::collections::VecDeque;

use super::router::{Flit, FlitKind, FlitQueues, FlitQueuesShard};
use super::routing::RouteTable;
use super::topology::{NodeId, Topology};
use crate::metrics::{Category, Metrics};
use crate::sim::{Cycle, EventWheel, StreamingHist, WorkerPool};

/// Microarchitectural NoC parameters (config defaults are FlooNoC-like).
#[derive(Debug, Clone, Copy)]
pub struct NocParams {
    pub vcs: usize,
    /// Input buffer depth per VC, in flits.
    pub buf_flits: usize,
    pub flit_bytes: usize,
    /// Router pipeline depth (cycles per hop).
    pub router_latency: Cycle,
    /// Link + router energy per bit per hop (pJ).
    pub hop_energy_pj_per_bit: f64,
    /// Worker threads for shard-parallel stepping (1 = sequential).
    /// Reports are bit-identical at every thread count — see the module
    /// docs' determinism contract.
    pub threads: usize,
}

impl Default for NocParams {
    fn default() -> Self {
        NocParams {
            vcs: 2,
            buf_flits: 4,
            flit_bytes: 32,
            router_latency: 3,
            hop_energy_pj_per_bit: 0.15,
            threads: 1,
        }
    }
}

impl NocParams {
    pub fn from_config(cfg: &crate::config::NocConfig) -> Self {
        NocParams {
            vcs: cfg.vcs,
            buf_flits: 4,
            flit_bytes: cfg.flit_bytes,
            router_latency: cfg.router_latency_cycles,
            hop_energy_pj_per_bit: cfg.hop_energy_pj_per_bit,
            threads: cfg.threads,
        }
    }
}

/// Lifetime record of one packet.
#[derive(Debug, Clone, Copy)]
pub struct PacketStats {
    pub src: NodeId,
    pub dst: NodeId,
    pub flits: usize,
    pub injected_at: Cycle,
    /// Cycle the tail flit was ejected (None while in flight).
    pub ejected_at: Option<Cycle>,
    pub hops: usize,
}

/// Aggregate simulation report (one bench-table row).
#[derive(Debug, Clone)]
pub struct SimReport {
    pub cycles: Cycle,
    pub delivered: usize,
    pub in_flight: usize,
    pub avg_latency: f64,
    pub p99_latency: f64,
    pub flit_hops: u64,
    /// Delivered flits per node per cycle.
    pub throughput: f64,
    pub metrics: Metrics,
}

/// An in-flight flit scheduled to land in a downstream input buffer.
#[derive(Debug, Clone, Copy)]
struct Arrival {
    node: NodeId,
    port: usize,
    flit: Flit,
}

/// A buffer-slot credit on its way back upstream.
#[derive(Debug, Clone, Copy)]
struct CreditReturn {
    node: NodeId,
    out_port: usize,
    vc: usize,
}

/// Sentinel for an unallocated output (port, vc).
const NO_OWNER: u32 = u32::MAX;

/// Per-shard side-effect buffer. During the parallel phase a shard only
/// appends here (in node order); the sequential merge applies every
/// scratch in global node order — see the module docs' determinism
/// contract.
#[derive(Debug, Default)]
struct ShardScratch {
    /// Flit arrivals to schedule on the global wheel.
    arrivals: Vec<(Cycle, Arrival)>,
    /// Credit returns to schedule on the global wheel (may target nodes
    /// in *other* shards — the upstream router of a boundary link).
    credit_returns: Vec<(Cycle, CreditReturn)>,
    /// Packets whose tail flit ejected this cycle (node order).
    ejections: Vec<usize>,
    /// Link traversals this cycle (merged into the global counter).
    flit_hops: u64,
    /// Per-cycle input-port busy scratch (sized `max_degree + 1`).
    input_busy: Vec<bool>,
}

impl ShardScratch {
    fn new(max_ports: usize) -> Self {
        ShardScratch { input_busy: vec![false; max_ports], ..Default::default() }
    }
}

/// Where a node phase's cross-node side effects go. Two zero-cost
/// implementations keep the loop body single-source: the sequential
/// (`threads = 1`) path pushes straight into the wheels and stats — the
/// exact pre-parallel hot loop, no buffering — while the parallel path
/// appends to a [`ShardScratch`] for the ordered merge.
trait Effects {
    fn hop(&mut self);
    fn credit(&mut self, at: Cycle, c: CreditReturn);
    fn arrival(&mut self, at: Cycle, a: Arrival);
    fn eject(&mut self, packet: usize);
}

/// Sequential sink: apply effects immediately (single-shard fast path).
struct DirectEffects<'a> {
    arrivals: &'a mut EventWheel<Arrival>,
    credit_returns: &'a mut EventWheel<CreditReturn>,
    packets: &'a mut [PacketStats],
    lat_hist: &'a mut StreamingHist,
    delivered: &'a mut usize,
    flit_hops: &'a mut u64,
    now_next: Cycle,
}

impl Effects for DirectEffects<'_> {
    #[inline]
    fn hop(&mut self) {
        *self.flit_hops += 1;
    }
    #[inline]
    fn credit(&mut self, at: Cycle, c: CreditReturn) {
        self.credit_returns.push(at, c);
    }
    #[inline]
    fn arrival(&mut self, at: Cycle, a: Arrival) {
        self.arrivals.push(at, a);
    }
    #[inline]
    fn eject(&mut self, packet: usize) {
        let p = &mut self.packets[packet];
        p.ejected_at = Some(self.now_next);
        self.lat_hist.record(self.now_next - p.injected_at);
        *self.delivered += 1;
    }
}

/// Parallel sink: buffer effects in node order for the sequential merge.
struct ScratchEffects<'a> {
    arrivals: &'a mut Vec<(Cycle, Arrival)>,
    credit_returns: &'a mut Vec<(Cycle, CreditReturn)>,
    ejections: &'a mut Vec<usize>,
    flit_hops: &'a mut u64,
}

impl Effects for ScratchEffects<'_> {
    #[inline]
    fn hop(&mut self) {
        *self.flit_hops += 1;
    }
    #[inline]
    fn credit(&mut self, at: Cycle, c: CreditReturn) {
        self.credit_returns.push((at, c));
    }
    #[inline]
    fn arrival(&mut self, at: Cycle, a: Arrival) {
        self.arrivals.push((at, a));
    }
    #[inline]
    fn eject(&mut self, packet: usize) {
        self.ejections.push(packet);
    }
}

/// Disjoint per-shard working set for one cycle: shared read-only
/// structure plus `&mut` views covering exactly the shard's node range.
/// `Send` by construction when the sink is (slices of `Send` data), so
/// instances can be moved to pool workers.
struct ShardCtx<'a, E> {
    topo: &'a Topology,
    routes: &'a RouteTable,
    qbase: &'a [usize],
    pbase: &'a [usize],
    bufs: FlitQueuesShard<'a>,
    credits: &'a mut [u32],
    owner: &'a mut [u32],
    rr: &'a mut [u32],
    occ: &'a mut [usize],
    inject_q: &'a mut [VecDeque<Flit>],
    input_busy: &'a mut [bool],
    effects: E,
    /// Node / queue / port offsets of this shard's ranges.
    n0: usize,
    n1: usize,
    q0: usize,
    p0: usize,
    vcs: usize,
    cap: usize,
    router_latency: Cycle,
}

/// The simulator.
pub struct NocSim {
    topo: Topology,
    routes: RouteTable,
    params: NocParams,
    /// All input buffers, flattened (see module docs for the layout).
    bufs: FlitQueues,
    /// credits[qbase[n] + out_port * vcs + vc] = free downstream slots.
    credits: Vec<u32>,
    /// owner[qbase[n] + out_port * vcs + vc] = `in_port * vcs + in_vc` of
    /// the packet holding the output, or [`NO_OWNER`].
    owner: Vec<u32>,
    /// Round-robin arbitration pointer per (node, output port).
    rr: Vec<u32>,
    /// First queue id of each node (`(degree + 1) * vcs` queues per node).
    qbase: Vec<usize>,
    /// First port id of each node (`degree + 1` ports per node).
    pbase: Vec<usize>,
    /// Total queue / port counts (the final prefix values).
    nq: usize,
    np: usize,
    /// Buffered flits per node — the active-node worklist: a node with no
    /// buffered flits and an empty source queue is skipped entirely.
    occ: Vec<usize>,
    /// Pending packet flits waiting at each source (unbounded source
    /// queue feeding the local injection port).
    inject_q: Vec<VecDeque<Flit>>,
    arrivals: EventWheel<Arrival>,
    credit_returns: EventWheel<CreditReturn>,
    /// Contiguous shard partition: node boundaries (len = shards + 1),
    /// plus the derived queue/port boundaries.
    shard_bounds: Vec<usize>,
    shard_qbounds: Vec<usize>,
    shard_pbounds: Vec<usize>,
    /// One side-effect buffer per shard, reused across cycles.
    scratch: Vec<ShardScratch>,
    /// Persistent workers (shards - 1 of them; the caller's thread runs
    /// shard 0). `None` when single-sharded.
    pool: Option<WorkerPool>,
    /// Streaming packet-latency stats, recorded at tail ejection, so
    /// `report()` is O(latency range) instead of sort-all-latencies.
    /// Quantiles are exact order statistics — bit-identical to the
    /// sorted-`Vec` path `refsim` retains (tests/noc_golden.rs).
    lat_hist: StreamingHist,
    packets: Vec<PacketStats>,
    now: Cycle,
    flit_hops: u64,
    delivered: usize,
}

impl NocSim {
    pub fn new(topo: Topology, params: NocParams) -> Self {
        assert!(params.vcs >= 1, "need at least one virtual channel");
        let routes = RouteTable::build(&topo);
        let nodes = topo.nodes();
        let vcs = params.vcs;
        let mut qbase = Vec::with_capacity(nodes);
        let mut pbase = Vec::with_capacity(nodes);
        let (mut q, mut p) = (0usize, 0usize);
        for n in 0..nodes {
            qbase.push(q);
            pbase.push(p);
            let ports = topo.degree(n) + 1;
            q += ports * vcs;
            p += ports;
        }
        let inject_q = (0..nodes).map(|_| VecDeque::new()).collect();
        let mut sim = NocSim {
            bufs: FlitQueues::new(q, params.buf_flits),
            credits: vec![params.buf_flits as u32; q],
            owner: vec![NO_OWNER; q],
            rr: vec![0; p],
            nq: q,
            np: p,
            occ: vec![0; nodes],
            inject_q,
            arrivals: EventWheel::with_horizon(params.router_latency as usize + 2),
            credit_returns: EventWheel::with_horizon(4),
            shard_bounds: Vec::new(),
            shard_qbounds: Vec::new(),
            shard_pbounds: Vec::new(),
            scratch: Vec::new(),
            pool: None,
            lat_hist: StreamingHist::new(),
            packets: Vec::new(),
            now: 0,
            flit_hops: 0,
            delivered: 0,
            topo,
            routes,
            params,
            qbase,
            pbase,
        };
        let bounds = partition_by_queues(&sim.qbase, sim.nq, nodes, params.threads.max(1));
        sim.apply_shards(bounds);
        sim
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    pub fn now(&self) -> Cycle {
        self.now
    }

    pub fn packets(&self) -> &[PacketStats] {
        &self.packets
    }

    /// Number of shards the node range is partitioned into.
    pub fn shards(&self) -> usize {
        self.shard_bounds.len() - 1
    }

    /// Override the shard partition with explicit node-index boundaries
    /// (`bounds[0] == 0`, strictly increasing, last == node count).
    /// Exposed for tuning and for the shard-invariance property tests:
    /// the determinism contract guarantees bit-identical reports for
    /// every valid partition.
    pub fn set_shards(&mut self, bounds: &[NodeId]) {
        let nodes = self.topo.nodes();
        assert!(bounds.len() >= 2, "need at least one shard");
        assert_eq!(bounds[0], 0, "bounds must start at node 0");
        assert_eq!(*bounds.last().unwrap(), nodes, "bounds must end at the node count");
        assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bounds must be strictly increasing");
        self.apply_shards(bounds.to_vec());
    }

    fn apply_shards(&mut self, bounds: Vec<usize>) {
        let nodes = self.topo.nodes();
        let nshards = bounds.len() - 1;
        self.shard_qbounds = bounds
            .iter()
            .map(|&b| if b == nodes { self.nq } else { self.qbase[b] })
            .collect();
        self.shard_pbounds = bounds
            .iter()
            .map(|&b| if b == nodes { self.np } else { self.pbase[b] })
            .collect();
        let ports = self.topo.max_degree() + 1;
        self.scratch = (0..nshards).map(|_| ShardScratch::new(ports)).collect();
        // Workers persist for the simulator's lifetime; the stepping
        // thread itself runs shard 0, so `shards - 1` workers suffice.
        self.pool = if nshards > 1 { Some(WorkerPool::new(nshards - 1)) } else { None };
        self.shard_bounds = bounds;
    }

    /// Queue a packet for injection at the current cycle. Returns its id.
    pub fn inject(&mut self, src: NodeId, dst: NodeId, bytes: usize) -> usize {
        assert!(src < self.topo.nodes() && dst < self.topo.nodes());
        assert_ne!(src, dst, "self-traffic is not modelled");
        let id = self.packets.len();
        let nflits = bytes.div_ceil(self.params.flit_bytes).max(1);
        let vc = id % self.params.vcs;
        for i in 0..nflits {
            let kind = if i + 1 == nflits {
                FlitKind::Tail
            } else if i == 0 {
                FlitKind::Head
            } else {
                FlitKind::Body
            };
            self.inject_q[src].push_back(Flit {
                packet: id,
                kind,
                is_head: i == 0,
                dst,
                vc,
            });
        }
        self.packets.push(PacketStats {
            src,
            dst,
            flits: nflits,
            injected_at: self.now,
            ejected_at: None,
            hops: self.routes.route_len(src, dst),
        });
        id
    }

    /// Advance one cycle. Sequential (`threads = 1`): one whole-arena
    /// pass with direct effect application — the exact pre-parallel hot
    /// loop. Sharded: parallel per-node phases into per-shard scratches,
    /// then the sequential node-order merge. Both end with event
    /// delivery, itself fanned out by destination node range in the
    /// sharded mode ([`NocSim::deliver_events`]).
    pub fn step(&mut self) {
        let now_next = self.now + 1;
        if self.shard_bounds.len() - 1 == 1 {
            self.step_single(now_next);
        } else {
            self.step_sharded(now_next);
            self.merge_scratches(now_next);
        }
        self.deliver_events(now_next);
        self.now = now_next;
    }

    /// Single-shard fast path: whole-arena view, direct pushes into the
    /// wheels and stats, no scratch buffering, no per-step allocation.
    fn step_single(&mut self, now_next: Cycle) {
        let now = self.now;
        let nodes = self.topo.nodes();
        let NocSim {
            topo,
            routes,
            params,
            bufs,
            credits,
            owner,
            rr,
            qbase,
            pbase,
            occ,
            inject_q,
            scratch,
            arrivals,
            credit_returns,
            packets,
            lat_hist,
            delivered,
            flit_hops,
            ..
        } = self;
        let mut ctx = ShardCtx {
            topo,
            routes,
            qbase,
            pbase,
            bufs: bufs.full_view(),
            credits,
            owner,
            rr,
            occ,
            inject_q,
            input_busy: &mut scratch[0].input_busy,
            effects: DirectEffects {
                arrivals,
                credit_returns,
                packets,
                lat_hist,
                delivered,
                flit_hops,
                now_next,
            },
            n0: 0,
            n1: nodes,
            q0: 0,
            p0: 0,
            vcs: params.vcs,
            cap: params.buf_flits,
            router_latency: params.router_latency,
        };
        ctx.run(now, now_next);
    }

    /// Phases 1–2 (injection, switch allocation + traversal) for every
    /// node, executed shard-parallel. All cross-shard effects land in
    /// the per-shard scratches for [`NocSim::merge_scratches`].
    fn step_sharded(&mut self, now_next: Cycle) {
        let nshards = self.shard_bounds.len() - 1;
        let now = self.now;
        let vcs = self.params.vcs;
        let cap = self.params.buf_flits;
        let router_latency = self.params.router_latency;
        let NocSim {
            topo,
            routes,
            bufs,
            credits,
            owner,
            rr,
            qbase,
            pbase,
            occ,
            inject_q,
            scratch,
            shard_bounds,
            shard_qbounds,
            shard_pbounds,
            pool,
            ..
        } = self;
        let topo: &Topology = topo;
        let routes: &RouteTable = routes;
        let qbase: &[usize] = qbase;
        let pbase: &[usize] = pbase;

        // Carve disjoint per-shard views out of the flat arenas, lazily:
        // each context is dispatched to a worker the moment it is built,
        // so the parallel step allocates no per-cycle `Vec` of views or
        // contexts (ROADMAP follow-up (a) to the PR 3 parallel port).
        let mut bufs_shards = bufs.shard_views(shard_qbounds);
        let (mut credits_r, mut owner_r) = (&mut credits[..], &mut owner[..]);
        let mut rr_r = &mut rr[..];
        let mut occ_r = &mut occ[..];
        let mut inj_r = &mut inject_q[..];
        let mut scratch_r = &mut scratch[..];
        let pool = pool.as_mut().expect("multi-shard sims own a worker pool");
        pool.scoped(|scope| {
            let mut first: Option<ShardCtx<'_, ScratchEffects<'_>>> = None;
            for i in 0..nshards {
                let bufs_sh = bufs_shards.next().expect("one view per shard");
                let (scr, rest) =
                    std::mem::take(&mut scratch_r).split_first_mut().expect("scratch per shard");
                scratch_r = rest;
                let (n0, n1) = (shard_bounds[i], shard_bounds[i + 1]);
                let (q0, q1) = (shard_qbounds[i], shard_qbounds[i + 1]);
                let (p0, p1) = (shard_pbounds[i], shard_pbounds[i + 1]);
                let (c, rest) = std::mem::take(&mut credits_r).split_at_mut(q1 - q0);
                credits_r = rest;
                let (ow, rest) = std::mem::take(&mut owner_r).split_at_mut(q1 - q0);
                owner_r = rest;
                let (r, rest) = std::mem::take(&mut rr_r).split_at_mut(p1 - p0);
                rr_r = rest;
                let (oc, rest) = std::mem::take(&mut occ_r).split_at_mut(n1 - n0);
                occ_r = rest;
                let (inj, rest) = std::mem::take(&mut inj_r).split_at_mut(n1 - n0);
                inj_r = rest;
                let ShardScratch { arrivals, credit_returns, ejections, flit_hops, input_busy } =
                    scr;
                let mut ctx = ShardCtx {
                    topo,
                    routes,
                    qbase,
                    pbase,
                    bufs: bufs_sh,
                    credits: c,
                    owner: ow,
                    rr: r,
                    occ: oc,
                    inject_q: inj,
                    input_busy,
                    effects: ScratchEffects { arrivals, credit_returns, ejections, flit_hops },
                    n0,
                    n1,
                    q0,
                    p0,
                    vcs,
                    cap,
                    router_latency,
                };
                if i == 0 {
                    first = Some(ctx);
                } else {
                    scope.execute(move || ctx.run(now, now_next));
                }
            }
            // The stepping thread works too instead of idling at the
            // barrier.
            first.expect("at least one shard").run(now, now_next);
        });
    }

    /// Sequential merge: apply every shard's side effects in global node
    /// order, replaying the single-thread push/record sequence exactly.
    fn merge_scratches(&mut self, now_next: Cycle) {
        let mut scratch = std::mem::take(&mut self.scratch);
        for s in &mut scratch {
            self.flit_hops += s.flit_hops;
            s.flit_hops = 0;
            self.arrivals.push_all(s.arrivals.drain(..));
            self.credit_returns.push_all(s.credit_returns.drain(..));
            for &pkt in &s.ejections {
                let p = &mut self.packets[pkt];
                p.ejected_at = Some(now_next);
                self.lat_hist.record(now_next - p.injected_at);
                self.delivered += 1;
            }
            s.ejections.clear();
        }
        self.scratch = scratch;
    }

    /// Phase 3: deliver events due at the end of this cycle. Multi-shard
    /// sims fan delivery out by destination node range so it stops being
    /// a sequential tail of the parallel step: each worker scans both due
    /// lists and applies only the entries landing in its own node range,
    /// through the same disjoint buffer/credit/occupancy views the step
    /// phases use. Arrivals and credit returns touch disjoint state
    /// (input buffers + occupancy vs. credit counters), and per-queue
    /// application order equals the due-list order in every shard, so the
    /// result is bit-identical to the sequential delivery loop (pinned by
    /// the tests/noc_golden.rs threads sweeps).
    fn deliver_events(&mut self, now_next: Cycle) {
        let vcs = self.params.vcs;
        let due = self.arrivals.take_due(now_next);
        let due_credits = self.credit_returns.take_due(now_next);
        if self.shard_bounds.len() - 1 > 1 && !(due.is_empty() && due_credits.is_empty()) {
            self.deliver_sharded(&due, &due_credits);
        } else {
            // Single-shard fast path: the exact pre-parallel delivery
            // loop (and the no-op path when nothing is due).
            for &(_, a) in &due {
                let q = self.qbase[a.node] + a.port * vcs + a.flit.vc;
                self.bufs.push_back(q, a.flit);
                self.occ[a.node] += 1;
            }
            for &(_, c) in &due_credits {
                self.credits[self.qbase[c.node] + c.out_port * vcs + c.vc] += 1;
            }
        }
        self.arrivals.recycle(due);
        self.credit_returns.recycle(due_credits);
    }

    /// Shard-parallel delivery: every worker filters the shared due lists
    /// down to its node range and applies them to its disjoint views. The
    /// stepping thread runs shard 0 — see [`NocSim::deliver_events`].
    fn deliver_sharded(&mut self, due: &[(Cycle, Arrival)], due_credits: &[(Cycle, CreditReturn)]) {
        let nshards = self.shard_bounds.len() - 1;
        let vcs = self.params.vcs;
        let NocSim { bufs, credits, occ, qbase, shard_bounds, shard_qbounds, pool, .. } = self;
        let qbase: &[usize] = qbase;
        let mut bufs_shards = bufs.shard_views(shard_qbounds);
        let mut credits_r = &mut credits[..];
        let mut occ_r = &mut occ[..];
        let pool = pool.as_mut().expect("multi-shard sims own a worker pool");
        pool.scoped(|scope| {
            let mut first = None;
            for i in 0..nshards {
                let bufs_sh = bufs_shards.next().expect("one view per shard");
                let (n0, n1) = (shard_bounds[i], shard_bounds[i + 1]);
                let (q0, q1) = (shard_qbounds[i], shard_qbounds[i + 1]);
                let (c, rest) = std::mem::take(&mut credits_r).split_at_mut(q1 - q0);
                credits_r = rest;
                let (oc, rest) = std::mem::take(&mut occ_r).split_at_mut(n1 - n0);
                occ_r = rest;
                if i == 0 {
                    first = Some((bufs_sh, c, oc, n0, n1, q0));
                } else {
                    scope.execute(move || {
                        deliver_range(bufs_sh, c, oc, qbase, n0, n1, q0, vcs, due, due_credits)
                    });
                }
            }
            // The stepping thread works too instead of idling at the
            // barrier.
            let (bufs_sh, c, oc, n0, n1, q0) = first.expect("at least one shard");
            deliver_range(bufs_sh, c, oc, qbase, n0, n1, q0, vcs, due, due_credits);
        });
    }

    /// True when no flits remain anywhere.
    pub fn drained(&self) -> bool {
        self.inject_q.iter().all(VecDeque::is_empty)
            && self.arrivals.is_empty()
            && self.occ.iter().all(|&o| o == 0)
    }

    /// Run until drained or `max_cycles`, then report.
    pub fn run_to_drain(&mut self, max_cycles: Cycle) -> SimReport {
        while !self.drained() && self.now < max_cycles {
            self.step();
        }
        self.report()
    }

    /// Run exactly `cycles` more cycles.
    pub fn run_for(&mut self, cycles: Cycle) {
        for _ in 0..cycles {
            self.step();
        }
    }

    pub fn report(&self) -> SimReport {
        // Streaming stats recorded at ejection: `mean` replays the same
        // u64 sum / f64 division as the replaced sorted-Vec code, and
        // `quantile_indexed` the same `(len-1).min(len*99/100)` index, so
        // both stay bit-identical to `refsim`'s report.
        let avg = self.lat_hist.mean();
        let p99 = self.lat_hist.quantile_indexed(99, 100);
        let mut metrics = Metrics::new();
        metrics.cycles = self.now;
        metrics.bytes_moved = self.flit_hops * self.params.flit_bytes as u64;
        metrics.add_energy(
            Category::Noc,
            self.flit_hops as f64 * self.params.flit_bytes as f64 * 8.0
                * self.params.hop_energy_pj_per_bit,
        );
        let delivered_flits: usize = self
            .packets
            .iter()
            .filter(|p| p.ejected_at.is_some())
            .map(|p| p.flits)
            .sum();
        SimReport {
            cycles: self.now,
            delivered: self.delivered,
            in_flight: self.packets.len() - self.delivered,
            avg_latency: avg,
            p99_latency: p99,
            flit_hops: self.flit_hops,
            throughput: if self.now == 0 {
                0.0
            } else {
                delivered_flits as f64 / self.now as f64 / self.topo.nodes() as f64
            },
            metrics,
        }
    }
}

/// Partition `0..nodes` into at most `shards` contiguous ranges balanced
/// by queue count (≈ buffer state per shard). Always returns a valid
/// boundary vector: starts at 0, strictly increasing, ends at `nodes`.
fn partition_by_queues(qbase: &[usize], total_q: usize, nodes: usize, shards: usize) -> Vec<usize> {
    let shards = shards.clamp(1, nodes.max(1));
    let mut bounds = Vec::with_capacity(shards + 1);
    bounds.push(0usize);
    for i in 1..shards {
        let target = total_q * i / shards;
        let b = qbase
            .partition_point(|&qb| qb < target)
            .max(bounds[i - 1] + 1)
            .min(nodes - (shards - i));
        bounds.push(b);
    }
    bounds.push(nodes);
    bounds
}

/// Apply the due arrivals / credit returns that land in node range
/// `[n0, n1)` to one shard's disjoint views. `bufs` is addressed by
/// global queue id (it subtracts its own offset); `credits` / `occ` are
/// the shard's slices, offset by `q0` / `n0`. Filtering preserves the
/// due-list order per queue, so sharded delivery replays the sequential
/// loop exactly — see [`NocSim::deliver_events`].
#[allow(clippy::too_many_arguments)]
fn deliver_range(
    mut bufs: FlitQueuesShard<'_>,
    credits: &mut [u32],
    occ: &mut [usize],
    qbase: &[usize],
    n0: usize,
    n1: usize,
    q0: usize,
    vcs: usize,
    due: &[(Cycle, Arrival)],
    due_credits: &[(Cycle, CreditReturn)],
) {
    for &(_, a) in due {
        if a.node < n0 || a.node >= n1 {
            continue;
        }
        bufs.push_back(qbase[a.node] + a.port * vcs + a.flit.vc, a.flit);
        occ[a.node - n0] += 1;
    }
    for &(_, c) in due_credits {
        if c.node < n0 || c.node >= n1 {
            continue;
        }
        credits[qbase[c.node] + c.out_port * vcs + c.vc - q0] += 1;
    }
}

impl<E: Effects> ShardCtx<'_, E> {
    /// Injection + switch allocation/traversal for every node in
    /// `n0..n1`. One loop body for both execution modes: offset
    /// indexing into the shard's `&mut` views, side effects routed
    /// through the [`Effects`] sink — direct pushes sequentially,
    /// scratch buffering in parallel (see the module docs' determinism
    /// contract).
    fn run(&mut self, now: Cycle, now_next: Cycle) {
        let vcs = self.vcs;
        let cap = self.cap;
        for n in self.n0..self.n1 {
            let ln = n - self.n0;
            // Worklist: idle routers (no buffered flits, nothing to
            // inject) can neither move flits nor change state — skip.
            if self.occ[ln] == 0 && self.inject_q[ln].is_empty() {
                continue;
            }
            let deg = self.topo.degree(n);
            let ports_in = deg + 1;
            let qb = self.qbase[n];

            // 1. Local injection: move flits from the source queue into
            //    the local input port's VC buffer while space remains.
            if !self.inject_q[ln].is_empty() {
                let local_q = qb + deg * vcs;
                loop {
                    let Some(&flit) = self.inject_q[ln].front() else { break };
                    let q = local_q + flit.vc;
                    if self.bufs.len(q) >= cap {
                        break;
                    }
                    let f = self.inject_q[ln].pop_front().unwrap();
                    self.bufs.push_back(q, f);
                    self.occ[ln] += 1;
                }
                if self.occ[ln] == 0 {
                    continue;
                }
            }

            // 2. Switch allocation + traversal, double-buffered. Output
            //    ports in fixed order: links first, then ejection.
            self.input_busy[..ports_in].fill(false);
            for p_out in 0..=deg {
                // 2a. VC allocation: head flits claim a free (p_out, vc).
                for p_in in 0..ports_in {
                    for vc in 0..vcs {
                        let Some(flit) = self.bufs.front(qb + p_in * vcs + vc) else {
                            continue;
                        };
                        if !flit.is_head {
                            continue; // body/tail follow the allocation
                        }
                        let want = if flit.dst == n {
                            deg
                        } else {
                            self.routes.out_port(n, flit.dst)
                        };
                        if want != p_out {
                            continue;
                        }
                        let o = qb + p_out * vcs + vc;
                        if self.owner[o - self.q0] == NO_OWNER {
                            self.owner[o - self.q0] = (p_in * vcs + vc) as u32;
                        }
                    }
                }
                // 2b. Switch traversal: round-robin over VCs that own this
                //     output; forward at most one flit per output port.
                let rrp = self.pbase[n] + p_out - self.p0;
                let rr0 = self.rr[rrp] as usize;
                for k in 0..vcs {
                    let vc = (rr0 + k) % vcs;
                    let o = qb + p_out * vcs + vc;
                    let own = self.owner[o - self.q0];
                    if own == NO_OWNER {
                        continue;
                    }
                    let p_in = own as usize / vcs;
                    let in_vc = own as usize % vcs;
                    if self.input_busy[p_in] {
                        continue;
                    }
                    let q = qb + p_in * vcs + in_vc;
                    let Some(flit) = self.bufs.front(q) else {
                        continue;
                    };
                    // Only flits of the owning packet may use the slot.
                    // The queue is FIFO per (port, vc); the owning
                    // packet's flits are contiguous (wormhole), so the
                    // front flit routed to this port belongs to it.
                    let want = if flit.dst == n {
                        deg
                    } else {
                        self.routes.out_port(n, flit.dst)
                    };
                    if want != p_out {
                        continue;
                    }
                    let is_ejection = p_out == deg;
                    if !is_ejection && self.credits[o - self.q0] == 0 {
                        continue;
                    }
                    // Commit the move.
                    let flit = self.bufs.pop_front(q);
                    self.occ[ln] -= 1;
                    self.input_busy[p_in] = true;
                    self.rr[rrp] = ((vc + 1) % vcs) as u32;
                    if flit.kind == FlitKind::Tail {
                        self.owner[o - self.q0] = NO_OWNER;
                    }
                    // Return a credit upstream for the buffer we freed
                    // (unless it was the local injection queue, which is
                    // backpressured directly). Credits are indexed by the
                    // upstream router's output port towards us — the
                    // precomputed reverse port. The upstream node may
                    // live in another shard, so this goes through the
                    // effects sink.
                    if p_in < deg {
                        let up = self.topo.neighbor(n, p_in);
                        let up_out = self.topo.reverse_port(n, p_in);
                        self.effects.credit(
                            now_next,
                            CreditReturn { node: up, out_port: up_out, vc: in_vc },
                        );
                    }
                    if is_ejection {
                        // Ejected at the local sink; the sink applies
                        // packet bookkeeping immediately (sequential) or
                        // defers it to the node-order merge (parallel).
                        if flit.kind == FlitKind::Tail {
                            self.effects.eject(flit.packet);
                        }
                    } else {
                        let next = self.topo.neighbor(n, p_out);
                        let dest_port = self.topo.reverse_port(n, p_out);
                        self.credits[o - self.q0] -= 1;
                        self.effects.hop();
                        let at = (now + self.router_latency).max(now_next);
                        self.effects
                            .arrival(at, Arrival { node: next, port: dest_port, flit });
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mesh_sim(w: usize, h: usize) -> NocSim {
        NocSim::new(Topology::mesh(w, h).unwrap(), NocParams::default())
    }

    #[test]
    fn single_packet_latency_matches_analytic() {
        let mut sim = mesh_sim(4, 4);
        // 0 -> 15: 6 hops; 64B = 2 flits.
        sim.inject(0, 15, 64);
        let rep = sim.run_to_drain(10_000);
        assert_eq!(rep.delivered, 1);
        let lat = sim.packets()[0].ejected_at.unwrap() - sim.packets()[0].injected_at;
        // serialization (2 flits) + hops * router_latency + inject/eject.
        let expect_min = 6 * 3; // hops * pipeline
        assert!(lat >= expect_min as u64, "lat {lat}");
        assert!(lat <= expect_min as u64 + 10, "lat {lat}");
    }

    #[test]
    fn all_packets_delivered_exactly_once() {
        let mut sim = mesh_sim(4, 4);
        let mut rng = crate::sim::Rng::new(7);
        for _ in 0..200 {
            let s = rng.below(16);
            let mut d = rng.below(16);
            while d == s {
                d = rng.below(16);
            }
            sim.inject(s, d, 32 + rng.below(97));
        }
        let rep = sim.run_to_drain(100_000);
        assert!(sim.drained(), "network drained");
        assert_eq!(rep.delivered, 200);
        assert_eq!(rep.in_flight, 0);
        assert!(sim.packets().iter().all(|p| p.ejected_at.is_some()));
    }

    #[test]
    fn torus_delivers_under_load() {
        let mut sim = NocSim::new(Topology::torus(4, 4).unwrap(), NocParams::default());
        let mut rng = crate::sim::Rng::new(3);
        for _ in 0..100 {
            let s = rng.below(16);
            let d = (s + 1 + rng.below(15)) % 16;
            sim.inject(s, d, 64);
        }
        let rep = sim.run_to_drain(100_000);
        assert_eq!(rep.delivered, 100);
    }

    #[test]
    fn hotspot_slower_than_uniform() {
        // All-to-one congests; same offered load spread uniformly drains
        // faster. (The paper's E2 saturation shape, in miniature.)
        let mut uni = mesh_sim(4, 4);
        let mut hot = mesh_sim(4, 4);
        let mut rng = crate::sim::Rng::new(11);
        for i in 0..60 {
            let s = (i * 5 + 1) % 16;
            let mut d = rng.below(16);
            while d == s {
                d = rng.below(16);
            }
            if s != 0 {
                hot.inject(s, 0, 128);
            }
            uni.inject(s, d, 128);
        }
        let ru = uni.run_to_drain(100_000);
        let rh = hot.run_to_drain(100_000);
        assert!(rh.cycles > ru.cycles, "hotspot {} vs uniform {}", rh.cycles, ru.cycles);
    }

    #[test]
    fn energy_scales_with_hops() {
        let mut near = mesh_sim(4, 4);
        near.inject(0, 1, 256);
        let rn = near.run_to_drain(10_000);
        let mut far = mesh_sim(4, 4);
        far.inject(0, 15, 256);
        let rf = far.run_to_drain(10_000);
        assert_eq!(rn.flit_hops * 6, rf.flit_hops); // 1 hop vs 6 hops
        let en = rn.metrics.total_energy_pj();
        let ef = rf.metrics.total_energy_pj();
        assert!((ef / en - 6.0).abs() < 1e-9);
    }

    #[test]
    fn flits_count_matches_bytes() {
        let mut sim = mesh_sim(2, 2);
        sim.inject(0, 1, 1); // 1 flit minimum
        sim.inject(0, 1, 32); // exactly 1
        sim.inject(0, 1, 33); // 2
        assert_eq!(sim.packets()[0].flits, 1);
        assert_eq!(sim.packets()[1].flits, 1);
        assert_eq!(sim.packets()[2].flits, 2);
    }

    #[test]
    #[should_panic(expected = "self-traffic")]
    fn rejects_self_traffic() {
        mesh_sim(2, 2).inject(1, 1, 32);
    }

    #[test]
    fn deterministic_replay() {
        let run = || {
            let mut sim = mesh_sim(4, 4);
            let mut rng = crate::sim::Rng::new(99);
            for _ in 0..150 {
                let s = rng.below(16);
                let mut d = rng.below(16);
                while d == s {
                    d = rng.below(16);
                }
                sim.inject(s, d, 64);
            }
            let r = sim.run_to_drain(100_000);
            (r.cycles, r.flit_hops, r.avg_latency.to_bits())
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn single_cycle_router_latency_still_drains() {
        // router_latency = 1 exercises the wheel's push-then-drain-same-
        // slot path (arrivals land one cycle out, like credits).
        let params = NocParams { router_latency: 1, ..NocParams::default() };
        let mut sim = NocSim::new(Topology::mesh(3, 3).unwrap(), params);
        let mut rng = crate::sim::Rng::new(21);
        for _ in 0..50 {
            let s = rng.below(9);
            let mut d = rng.below(9);
            while d == s {
                d = rng.below(9);
            }
            sim.inject(s, d, 96);
        }
        let rep = sim.run_to_drain(100_000);
        assert_eq!(rep.delivered, 50);
        assert!(sim.drained());
    }

    #[test]
    fn single_vc_wormhole_drains() {
        let params = NocParams { vcs: 1, ..NocParams::default() };
        let mut sim = NocSim::new(Topology::mesh(4, 4).unwrap(), params);
        let mut rng = crate::sim::Rng::new(5);
        for _ in 0..80 {
            let s = rng.below(16);
            let mut d = rng.below(16);
            while d == s {
                d = rng.below(16);
            }
            sim.inject(s, d, 128);
        }
        let rep = sim.run_to_drain(200_000);
        assert_eq!(rep.delivered, 80);
    }

    #[test]
    fn threaded_step_matches_sequential_bitwise() {
        // The cheap in-module determinism check; the full sweep against
        // refsim lives in tests/noc_golden.rs.
        let run = |threads: usize| {
            let params = NocParams { threads, ..NocParams::default() };
            let mut sim = NocSim::new(Topology::mesh(4, 4).unwrap(), params);
            let mut rng = crate::sim::Rng::new(31);
            for _ in 0..120 {
                let s = rng.below(16);
                let mut d = rng.below(16);
                while d == s {
                    d = rng.below(16);
                }
                sim.inject(s, d, 16 + rng.below(150));
            }
            let r = sim.run_to_drain(200_000);
            (r.cycles, r.delivered, r.flit_hops, r.avg_latency.to_bits(), r.p99_latency.to_bits())
        };
        let base = run(1);
        for threads in [2, 3, 8] {
            assert_eq!(run(threads), base, "threads={threads}");
        }
    }

    #[test]
    fn partition_is_valid_for_all_shapes() {
        // Uneven degrees (star: hub has n-1 ports, leaves 1) still yield
        // valid, nonempty, covering partitions.
        for (nodes, shards) in [(1, 4), (2, 2), (9, 3), (9, 9), (16, 5), (64, 8)] {
            let topo = if nodes == 1 {
                Topology::custom(1, &[]).unwrap()
            } else {
                Topology::star(nodes).unwrap()
            };
            let sim = NocSim::new(topo, NocParams { threads: shards, ..NocParams::default() });
            let b = &sim.shard_bounds;
            assert_eq!(b[0], 0);
            assert_eq!(*b.last().unwrap(), nodes);
            assert!(b.windows(2).all(|w| w[0] < w[1]), "{b:?}");
            assert!(b.len() - 1 <= shards.min(nodes.max(1)));
        }
    }

    #[test]
    fn set_shards_rejects_bad_bounds() {
        let mut sim = mesh_sim(3, 3);
        sim.set_shards(&[0, 4, 9]); // valid
        assert_eq!(sim.shards(), 2);
        for bad in [vec![1, 9], vec![0, 4], vec![0, 4, 4, 9], vec![0usize; 0]] {
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let mut s = mesh_sim(3, 3);
                s.set_shards(&bad);
            }));
            assert!(r.is_err(), "bounds {bad:?} must be rejected");
        }
    }
}
