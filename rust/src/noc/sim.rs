//! Cycle-stepped wormhole NoC simulation with virtual channels and credit
//! flow control.
//!
//! Model (one clock domain, all routers step synchronously):
//! * A packet is flitized at injection (`ceil(bytes / flit_bytes)` flits,
//!   head…tail) and assigned a VC (`packet_id % vcs`).
//! * Each router has one input port per incident link plus a local
//!   injection port, and one output port per link plus a local ejection
//!   port. Per cycle each input port sends at most one flit and each
//!   output port accepts at most one flit (crossbar constraint).
//! * A head flit arbitrates (round-robin) for its routed output port and
//!   allocates (port, vc) until its tail passes — wormhole switching.
//! * Forwarding consumes one downstream credit; credits return to the
//!   upstream router one cycle after the downstream buffer drains.
//! * A forwarded flit arrives `router_latency` cycles later at the next
//!   router (pipeline depth), 1 flit/cycle/link throughput.
//!
//! Determinism: routers and ports are iterated in fixed order, all moves
//! are double-buffered within a cycle, and all randomness lives in the
//! traffic generators (seeded).
//!
//! # Hot-loop layout (event-wheel rewrite)
//!
//! The cycle loop is flat and allocation-free in steady state:
//!
//! * **Buffers** — all (node, port, vc) input queues live in one
//!   contiguous [`FlitQueues`] arena. Queue ids are dense:
//!   `qbase[n] + port * vcs + vc`, with `qbase` the per-node prefix sum
//!   of `(degree + 1) * vcs`. Credits and output-owner state use the
//!   same indexing in flat arrays (`credits`, `owner`), and round-robin
//!   pointers use the analogous per-port prefix (`pbase[n] + port`).
//! * **Events** — in-flight flits and credit returns live in two
//!   [`EventWheel`] calendar queues instead of unsorted `Vec`s that were
//!   drained and reallocated every cycle. Push is O(1); the end-of-cycle
//!   drain hands back the due bucket's storage, which is recycled.
//! * **Routing** — the output port towards a destination is a single
//!   table read ([`RouteTable::out_port`]), and the far-end input port of
//!   every link is a precomputed reverse-port lookup
//!   ([`Topology::reverse_port`]); the old code recomputed both with
//!   linear neighbor scans per flit per cycle.
//! * **Worklist** — per-node buffered-flit counts (`occ`) let the loop
//!   skip idle routers outright: an empty router with an empty source
//!   queue cannot allocate, traverse, or emit events, so skipping it is
//!   exactly behavior-preserving.
//!
//! Behavior is pinned by differential golden tests against
//! [`super::refsim::RefNocSim`], the retained pre-rewrite implementation:
//! on fixed seeds both produce bit-identical [`SimReport`]s (see
//! `tests/noc_golden.rs`).

use std::collections::VecDeque;

use super::router::{Flit, FlitKind, FlitQueues};
use super::routing::RouteTable;
use super::topology::{NodeId, Topology};
use crate::metrics::{Category, Metrics};
use crate::sim::{Cycle, EventWheel, StreamingHist};

/// Microarchitectural NoC parameters (config defaults are FlooNoC-like).
#[derive(Debug, Clone, Copy)]
pub struct NocParams {
    pub vcs: usize,
    /// Input buffer depth per VC, in flits.
    pub buf_flits: usize,
    pub flit_bytes: usize,
    /// Router pipeline depth (cycles per hop).
    pub router_latency: Cycle,
    /// Link + router energy per bit per hop (pJ).
    pub hop_energy_pj_per_bit: f64,
}

impl Default for NocParams {
    fn default() -> Self {
        NocParams {
            vcs: 2,
            buf_flits: 4,
            flit_bytes: 32,
            router_latency: 3,
            hop_energy_pj_per_bit: 0.15,
        }
    }
}

impl NocParams {
    pub fn from_config(cfg: &crate::config::NocConfig) -> Self {
        NocParams {
            vcs: cfg.vcs,
            buf_flits: 4,
            flit_bytes: cfg.flit_bytes,
            router_latency: cfg.router_latency_cycles,
            hop_energy_pj_per_bit: cfg.hop_energy_pj_per_bit,
        }
    }
}

/// Lifetime record of one packet.
#[derive(Debug, Clone, Copy)]
pub struct PacketStats {
    pub src: NodeId,
    pub dst: NodeId,
    pub flits: usize,
    pub injected_at: Cycle,
    /// Cycle the tail flit was ejected (None while in flight).
    pub ejected_at: Option<Cycle>,
    pub hops: usize,
}

/// Aggregate simulation report (one bench-table row).
#[derive(Debug, Clone)]
pub struct SimReport {
    pub cycles: Cycle,
    pub delivered: usize,
    pub in_flight: usize,
    pub avg_latency: f64,
    pub p99_latency: f64,
    pub flit_hops: u64,
    /// Delivered flits per node per cycle.
    pub throughput: f64,
    pub metrics: Metrics,
}

/// An in-flight flit scheduled to land in a downstream input buffer.
#[derive(Debug, Clone, Copy)]
struct Arrival {
    node: NodeId,
    port: usize,
    flit: Flit,
}

/// A buffer-slot credit on its way back upstream.
#[derive(Debug, Clone, Copy)]
struct CreditReturn {
    node: NodeId,
    out_port: usize,
    vc: usize,
}

/// Sentinel for an unallocated output (port, vc).
const NO_OWNER: u32 = u32::MAX;

/// The simulator.
pub struct NocSim {
    topo: Topology,
    routes: RouteTable,
    params: NocParams,
    /// All input buffers, flattened (see module docs for the layout).
    bufs: FlitQueues,
    /// credits[qbase[n] + out_port * vcs + vc] = free downstream slots.
    credits: Vec<u32>,
    /// owner[qbase[n] + out_port * vcs + vc] = `in_port * vcs + in_vc` of
    /// the packet holding the output, or [`NO_OWNER`].
    owner: Vec<u32>,
    /// Round-robin arbitration pointer per (node, output port).
    rr: Vec<u32>,
    /// First queue id of each node (`(degree + 1) * vcs` queues per node).
    qbase: Vec<usize>,
    /// First port id of each node (`degree + 1` ports per node).
    pbase: Vec<usize>,
    /// Buffered flits per node — the active-node worklist: a node with no
    /// buffered flits and an empty source queue is skipped entirely.
    occ: Vec<usize>,
    /// Pending packet flits waiting at each source (unbounded source
    /// queue feeding the local injection port).
    inject_q: Vec<VecDeque<Flit>>,
    arrivals: EventWheel<Arrival>,
    credit_returns: EventWheel<CreditReturn>,
    /// Per-cycle scratch, reused across steps (sized `max_degree + 1`).
    input_busy: Vec<bool>,
    /// Streaming packet-latency stats, recorded at tail ejection, so
    /// `report()` is O(latency range) instead of sort-all-latencies.
    /// Quantiles are exact order statistics — bit-identical to the
    /// sorted-`Vec` path `refsim` retains (tests/noc_golden.rs).
    lat_hist: StreamingHist,
    packets: Vec<PacketStats>,
    now: Cycle,
    flit_hops: u64,
    delivered: usize,
}

impl NocSim {
    pub fn new(topo: Topology, params: NocParams) -> Self {
        let routes = RouteTable::build(&topo);
        let nodes = topo.nodes();
        let vcs = params.vcs;
        let mut qbase = Vec::with_capacity(nodes);
        let mut pbase = Vec::with_capacity(nodes);
        let (mut q, mut p) = (0usize, 0usize);
        for n in 0..nodes {
            qbase.push(q);
            pbase.push(p);
            let ports = topo.degree(n) + 1;
            q += ports * vcs;
            p += ports;
        }
        let inject_q = (0..nodes).map(|_| VecDeque::new()).collect();
        NocSim {
            bufs: FlitQueues::new(q, params.buf_flits),
            credits: vec![params.buf_flits as u32; q],
            owner: vec![NO_OWNER; q],
            rr: vec![0; p],
            qbase,
            pbase,
            occ: vec![0; nodes],
            inject_q,
            arrivals: EventWheel::with_horizon(params.router_latency as usize + 2),
            credit_returns: EventWheel::with_horizon(4),
            input_busy: vec![false; topo.max_degree() + 1],
            lat_hist: StreamingHist::new(),
            packets: Vec::new(),
            now: 0,
            flit_hops: 0,
            delivered: 0,
            topo,
            routes,
            params,
        }
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    pub fn now(&self) -> Cycle {
        self.now
    }

    pub fn packets(&self) -> &[PacketStats] {
        &self.packets
    }

    /// Queue a packet for injection at the current cycle. Returns its id.
    pub fn inject(&mut self, src: NodeId, dst: NodeId, bytes: usize) -> usize {
        assert!(src < self.topo.nodes() && dst < self.topo.nodes());
        assert_ne!(src, dst, "self-traffic is not modelled");
        let id = self.packets.len();
        let nflits = bytes.div_ceil(self.params.flit_bytes).max(1);
        let vc = id % self.params.vcs;
        for i in 0..nflits {
            let kind = if i + 1 == nflits {
                FlitKind::Tail
            } else if i == 0 {
                FlitKind::Head
            } else {
                FlitKind::Body
            };
            self.inject_q[src].push_back(Flit {
                packet: id,
                kind,
                is_head: i == 0,
                dst,
                vc,
            });
        }
        self.packets.push(PacketStats {
            src,
            dst,
            flits: nflits,
            injected_at: self.now,
            ejected_at: None,
            hops: self.routes.route_len(src, dst),
        });
        id
    }

    /// Advance one cycle.
    pub fn step(&mut self) {
        let vcs = self.params.vcs;
        let cap = self.params.buf_flits;
        let now_next = self.now + 1;
        let nodes = self.topo.nodes();

        for n in 0..nodes {
            // Worklist: idle routers (no buffered flits, nothing to
            // inject) can neither move flits nor change state — skip.
            if self.occ[n] == 0 && self.inject_q[n].is_empty() {
                continue;
            }
            let deg = self.topo.degree(n);
            let ports_in = deg + 1;
            let qb = self.qbase[n];

            // 1. Local injection: move flits from the source queue into
            //    the local input port's VC buffer while space remains.
            if !self.inject_q[n].is_empty() {
                let local_q = qb + deg * vcs;
                loop {
                    let Some(&flit) = self.inject_q[n].front() else { break };
                    let q = local_q + flit.vc;
                    if self.bufs.len(q) >= cap {
                        break;
                    }
                    let f = self.inject_q[n].pop_front().unwrap();
                    self.bufs.push_back(q, f);
                    self.occ[n] += 1;
                }
                if self.occ[n] == 0 {
                    continue;
                }
            }

            // 2. Switch allocation + traversal, double-buffered. Output
            //    ports in fixed order: links first, then ejection.
            self.input_busy[..ports_in].fill(false);
            for p_out in 0..=deg {
                // 2a. VC allocation: head flits claim a free (p_out, vc).
                for p_in in 0..ports_in {
                    for vc in 0..vcs {
                        let Some(flit) = self.bufs.front(qb + p_in * vcs + vc) else {
                            continue;
                        };
                        if !flit.is_head {
                            continue; // body/tail follow the allocation
                        }
                        let want = if flit.dst == n {
                            deg
                        } else {
                            self.routes.out_port(n, flit.dst)
                        };
                        if want != p_out {
                            continue;
                        }
                        let o = qb + p_out * vcs + vc;
                        if self.owner[o] == NO_OWNER {
                            self.owner[o] = (p_in * vcs + vc) as u32;
                        }
                    }
                }
                // 2b. Switch traversal: round-robin over VCs that own this
                //     output; forward at most one flit per output port.
                let rr0 = self.rr[self.pbase[n] + p_out] as usize;
                for k in 0..vcs {
                    let vc = (rr0 + k) % vcs;
                    let o = qb + p_out * vcs + vc;
                    let own = self.owner[o];
                    if own == NO_OWNER {
                        continue;
                    }
                    let p_in = own as usize / vcs;
                    let in_vc = own as usize % vcs;
                    if self.input_busy[p_in] {
                        continue;
                    }
                    let q = qb + p_in * vcs + in_vc;
                    let Some(flit) = self.bufs.front(q) else {
                        continue;
                    };
                    // Only flits of the owning packet may use the slot.
                    // The queue is FIFO per (port, vc); the owning
                    // packet's flits are contiguous (wormhole), so the
                    // front flit routed to this port belongs to it.
                    let want = if flit.dst == n {
                        deg
                    } else {
                        self.routes.out_port(n, flit.dst)
                    };
                    if want != p_out {
                        continue;
                    }
                    let is_ejection = p_out == deg;
                    if !is_ejection && self.credits[o] == 0 {
                        continue;
                    }
                    // Commit the move.
                    let flit = self.bufs.pop_front(q);
                    self.occ[n] -= 1;
                    self.input_busy[p_in] = true;
                    self.rr[self.pbase[n] + p_out] = ((vc + 1) % vcs) as u32;
                    if flit.kind == FlitKind::Tail {
                        self.owner[o] = NO_OWNER;
                    }
                    // Return a credit upstream for the buffer we freed
                    // (unless it was the local injection queue, which is
                    // backpressured directly). Credits are indexed by the
                    // upstream router's output port towards us — the
                    // precomputed reverse port.
                    if p_in < deg {
                        let up = self.topo.neighbor(n, p_in);
                        let up_out = self.topo.reverse_port(n, p_in);
                        self.credit_returns.push(
                            now_next,
                            CreditReturn { node: up, out_port: up_out, vc: in_vc },
                        );
                    }
                    if is_ejection {
                        // Ejected at the local sink.
                        if flit.kind == FlitKind::Tail {
                            let p = &mut self.packets[flit.packet];
                            p.ejected_at = Some(now_next);
                            self.lat_hist.record(now_next - p.injected_at);
                            self.delivered += 1;
                        }
                    } else {
                        let next = self.topo.neighbor(n, p_out);
                        let dest_port = self.topo.reverse_port(n, p_out);
                        self.credits[o] -= 1;
                        self.flit_hops += 1;
                        let at = (self.now + self.params.router_latency).max(now_next);
                        self.arrivals.push(at, Arrival { node: next, port: dest_port, flit });
                    }
                }
            }
        }

        // 3. Deliver events due at the end of this cycle.
        let due = self.arrivals.take_due(now_next);
        for &(_, a) in &due {
            let q = self.qbase[a.node] + a.port * vcs + a.flit.vc;
            self.bufs.push_back(q, a.flit);
            self.occ[a.node] += 1;
        }
        self.arrivals.recycle(due);
        let due = self.credit_returns.take_due(now_next);
        for &(_, c) in &due {
            self.credits[self.qbase[c.node] + c.out_port * vcs + c.vc] += 1;
        }
        self.credit_returns.recycle(due);

        self.now = now_next;
    }

    /// True when no flits remain anywhere.
    pub fn drained(&self) -> bool {
        self.inject_q.iter().all(VecDeque::is_empty)
            && self.arrivals.is_empty()
            && self.occ.iter().all(|&o| o == 0)
    }

    /// Run until drained or `max_cycles`, then report.
    pub fn run_to_drain(&mut self, max_cycles: Cycle) -> SimReport {
        while !self.drained() && self.now < max_cycles {
            self.step();
        }
        self.report()
    }

    /// Run exactly `cycles` more cycles.
    pub fn run_for(&mut self, cycles: Cycle) {
        for _ in 0..cycles {
            self.step();
        }
    }

    pub fn report(&self) -> SimReport {
        // Streaming stats recorded at ejection: `mean` replays the same
        // u64 sum / f64 division as the replaced sorted-Vec code, and
        // `quantile_indexed` the same `(len-1).min(len*99/100)` index, so
        // both stay bit-identical to `refsim`'s report.
        let avg = self.lat_hist.mean();
        let p99 = self.lat_hist.quantile_indexed(99, 100);
        let mut metrics = Metrics::new();
        metrics.cycles = self.now;
        metrics.bytes_moved = self.flit_hops * self.params.flit_bytes as u64;
        metrics.add_energy(
            Category::Noc,
            self.flit_hops as f64 * self.params.flit_bytes as f64 * 8.0
                * self.params.hop_energy_pj_per_bit,
        );
        let delivered_flits: usize = self
            .packets
            .iter()
            .filter(|p| p.ejected_at.is_some())
            .map(|p| p.flits)
            .sum();
        SimReport {
            cycles: self.now,
            delivered: self.delivered,
            in_flight: self.packets.len() - self.delivered,
            avg_latency: avg,
            p99_latency: p99,
            flit_hops: self.flit_hops,
            throughput: if self.now == 0 {
                0.0
            } else {
                delivered_flits as f64 / self.now as f64 / self.topo.nodes() as f64
            },
            metrics,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mesh_sim(w: usize, h: usize) -> NocSim {
        NocSim::new(Topology::mesh(w, h).unwrap(), NocParams::default())
    }

    #[test]
    fn single_packet_latency_matches_analytic() {
        let mut sim = mesh_sim(4, 4);
        // 0 -> 15: 6 hops; 64B = 2 flits.
        sim.inject(0, 15, 64);
        let rep = sim.run_to_drain(10_000);
        assert_eq!(rep.delivered, 1);
        let lat = sim.packets()[0].ejected_at.unwrap() - sim.packets()[0].injected_at;
        // serialization (2 flits) + hops * router_latency + inject/eject.
        let expect_min = 6 * 3; // hops * pipeline
        assert!(lat >= expect_min as u64, "lat {lat}");
        assert!(lat <= expect_min as u64 + 10, "lat {lat}");
    }

    #[test]
    fn all_packets_delivered_exactly_once() {
        let mut sim = mesh_sim(4, 4);
        let mut rng = crate::sim::Rng::new(7);
        for _ in 0..200 {
            let s = rng.below(16);
            let mut d = rng.below(16);
            while d == s {
                d = rng.below(16);
            }
            sim.inject(s, d, 32 + rng.below(97));
        }
        let rep = sim.run_to_drain(100_000);
        assert!(sim.drained(), "network drained");
        assert_eq!(rep.delivered, 200);
        assert_eq!(rep.in_flight, 0);
        assert!(sim.packets().iter().all(|p| p.ejected_at.is_some()));
    }

    #[test]
    fn torus_delivers_under_load() {
        let mut sim = NocSim::new(Topology::torus(4, 4).unwrap(), NocParams::default());
        let mut rng = crate::sim::Rng::new(3);
        for _ in 0..100 {
            let s = rng.below(16);
            let d = (s + 1 + rng.below(15)) % 16;
            sim.inject(s, d, 64);
        }
        let rep = sim.run_to_drain(100_000);
        assert_eq!(rep.delivered, 100);
    }

    #[test]
    fn hotspot_slower_than_uniform() {
        // All-to-one congests; same offered load spread uniformly drains
        // faster. (The paper's E2 saturation shape, in miniature.)
        let mut uni = mesh_sim(4, 4);
        let mut hot = mesh_sim(4, 4);
        let mut rng = crate::sim::Rng::new(11);
        for i in 0..60 {
            let s = (i * 5 + 1) % 16;
            let mut d = rng.below(16);
            while d == s {
                d = rng.below(16);
            }
            if s != 0 {
                hot.inject(s, 0, 128);
            }
            uni.inject(s, d, 128);
        }
        let ru = uni.run_to_drain(100_000);
        let rh = hot.run_to_drain(100_000);
        assert!(rh.cycles > ru.cycles, "hotspot {} vs uniform {}", rh.cycles, ru.cycles);
    }

    #[test]
    fn energy_scales_with_hops() {
        let mut near = mesh_sim(4, 4);
        near.inject(0, 1, 256);
        let rn = near.run_to_drain(10_000);
        let mut far = mesh_sim(4, 4);
        far.inject(0, 15, 256);
        let rf = far.run_to_drain(10_000);
        assert_eq!(rn.flit_hops * 6, rf.flit_hops); // 1 hop vs 6 hops
        let en = rn.metrics.total_energy_pj();
        let ef = rf.metrics.total_energy_pj();
        assert!((ef / en - 6.0).abs() < 1e-9);
    }

    #[test]
    fn flits_count_matches_bytes() {
        let mut sim = mesh_sim(2, 2);
        sim.inject(0, 1, 1); // 1 flit minimum
        sim.inject(0, 1, 32); // exactly 1
        sim.inject(0, 1, 33); // 2
        assert_eq!(sim.packets()[0].flits, 1);
        assert_eq!(sim.packets()[1].flits, 1);
        assert_eq!(sim.packets()[2].flits, 2);
    }

    #[test]
    #[should_panic(expected = "self-traffic")]
    fn rejects_self_traffic() {
        mesh_sim(2, 2).inject(1, 1, 32);
    }

    #[test]
    fn deterministic_replay() {
        let run = || {
            let mut sim = mesh_sim(4, 4);
            let mut rng = crate::sim::Rng::new(99);
            for _ in 0..150 {
                let s = rng.below(16);
                let mut d = rng.below(16);
                while d == s {
                    d = rng.below(16);
                }
                sim.inject(s, d, 64);
            }
            let r = sim.run_to_drain(100_000);
            (r.cycles, r.flit_hops, r.avg_latency.to_bits())
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn single_cycle_router_latency_still_drains() {
        // router_latency = 1 exercises the wheel's push-then-drain-same-
        // slot path (arrivals land one cycle out, like credits).
        let params = NocParams { router_latency: 1, ..NocParams::default() };
        let mut sim = NocSim::new(Topology::mesh(3, 3).unwrap(), params);
        let mut rng = crate::sim::Rng::new(21);
        for _ in 0..50 {
            let s = rng.below(9);
            let mut d = rng.below(9);
            while d == s {
                d = rng.below(9);
            }
            sim.inject(s, d, 96);
        }
        let rep = sim.run_to_drain(100_000);
        assert_eq!(rep.delivered, 50);
        assert!(sim.drained());
    }

    #[test]
    fn single_vc_wormhole_drains() {
        let params = NocParams { vcs: 1, ..NocParams::default() };
        let mut sim = NocSim::new(Topology::mesh(4, 4).unwrap(), params);
        let mut rng = crate::sim::Rng::new(5);
        for _ in 0..80 {
            let s = rng.below(16);
            let mut d = rng.below(16);
            while d == s {
                d = rng.below(16);
            }
            sim.inject(s, d, 128);
        }
        let rep = sim.run_to_drain(200_000);
        assert_eq!(rep.delivered, 80);
    }
}
