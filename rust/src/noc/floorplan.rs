//! Approximate NoC floorplanning and link-routing cost estimation
//! (paper Sec. III: "a toolchain incorporating approximate NoC
//! floor-planning and link routing to provide rapid yet precise cost and
//! performance estimations").
//!
//! Tiles are placed on a √N×√N grid; regular topologies use their natural
//! coordinates, custom graphs get a greedy connectivity-aware placement.
//! Link length = Manhattan distance in tile pitches; per-link latency and
//! energy derate linearly with length (repeated wires), which is the
//! first-order model FlooNoC's physical design validates.

use super::topology::{Topology, TopologyKind};

/// Cost of one physical link after placement.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkCost {
    /// Endpoint nodes.
    pub a: usize,
    pub b: usize,
    /// Manhattan length in tile pitches (>= 1).
    pub length: usize,
    /// Extra pipeline cycles from wire length (1 cycle per pitch beyond
    /// the first).
    pub extra_cycles: u64,
    /// Energy multiplier vs a unit-length link.
    pub energy_scale: f64,
}

/// A placed topology with per-link costs.
#[derive(Debug, Clone)]
pub struct Floorplan {
    /// Tile position of each node (grid coordinates).
    pub pos: Vec<(usize, usize)>,
    pub links: Vec<LinkCost>,
    /// Die edge in tiles.
    pub grid: usize,
}

impl Floorplan {
    /// Place `topo` and cost its links. `tile_mm` is the tile pitch used
    /// for the area report.
    pub fn place(topo: &Topology) -> Floorplan {
        let n = topo.nodes();
        let grid = (n as f64).sqrt().ceil() as usize;
        let pos = match topo.kind() {
            TopologyKind::Mesh { w, .. } | TopologyKind::Torus { w, .. } => {
                (0..n).map(|i| (i % w, i / w)).collect::<Vec<_>>()
            }
            _ => greedy_place(topo, grid),
        };
        let mut links = Vec::with_capacity(topo.links());
        let mut seen = std::collections::HashSet::new();
        for a in 0..n {
            for &(b, lid) in topo.neighbors(a) {
                if !seen.insert(lid) {
                    continue;
                }
                let (ax, ay) = pos[a];
                let (bx, by) = pos[b];
                let length = ax.abs_diff(bx) + ay.abs_diff(by);
                let length = length.max(1);
                links.push(LinkCost {
                    a,
                    b,
                    length,
                    extra_cycles: (length - 1) as u64,
                    energy_scale: length as f64,
                });
            }
        }
        Floorplan { pos, links, grid }
    }

    /// Total wire length (tile pitches) — the DSE area/cost proxy.
    pub fn total_wirelength(&self) -> usize {
        self.links.iter().map(|l| l.length).sum()
    }

    /// Longest link (sets the safe clock or pipelining depth).
    pub fn max_link_length(&self) -> usize {
        self.links.iter().map(|l| l.length).max().unwrap_or(0)
    }

    /// Mean energy scale over links (≥ 1.0; 1.0 = all unit-length).
    pub fn avg_energy_scale(&self) -> f64 {
        if self.links.is_empty() {
            return 1.0;
        }
        self.links.iter().map(|l| l.energy_scale).sum::<f64>() / self.links.len() as f64
    }

    /// Die area in mm² given a tile pitch.
    pub fn die_area_mm2(&self, tile_mm: f64) -> f64 {
        (self.grid as f64 * tile_mm).powi(2)
    }
}

/// Greedy DFS placement: nodes are laid out in DFS order from the
/// highest-degree node, snaking over the grid — DFS follows chains, so
/// graph neighbours land in adjacent slots and most links stay short
/// (exactly right for rings/paths, good for trees and low-radix graphs).
fn greedy_place(topo: &Topology, grid: usize) -> Vec<(usize, usize)> {
    let n = topo.nodes();
    let start = (0..n).max_by_key(|&v| topo.degree(v)).unwrap_or(0);
    let mut order = Vec::with_capacity(n);
    let mut seen = vec![false; n];
    let mut stack = vec![start];
    seen[start] = true;
    while let Some(u) = stack.pop() {
        order.push(u);
        for &(v, _) in topo.neighbors(u).iter().rev() {
            if !seen[v] {
                seen[v] = true;
                stack.push(v);
            }
        }
    }
    // Disconnected leftovers at the end.
    for v in 0..n {
        if !seen[v] {
            order.push(v);
        }
    }
    let mut pos = vec![(0, 0); n];
    for (slot, &node) in order.iter().enumerate() {
        let y = slot / grid;
        let x = if y % 2 == 0 { slot % grid } else { grid - 1 - (slot % grid) };
        pos[node] = (x, y);
    }
    pos
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mesh_links_are_unit_length() {
        let t = Topology::mesh(4, 4).unwrap();
        let fp = Floorplan::place(&t);
        assert!(fp.links.iter().all(|l| l.length == 1));
        assert_eq!(fp.total_wirelength(), t.links());
        assert_eq!(fp.avg_energy_scale(), 1.0);
    }

    #[test]
    fn torus_wrap_links_are_long() {
        let t = Topology::torus(4, 4).unwrap();
        let fp = Floorplan::place(&t);
        assert_eq!(fp.max_link_length(), 3);
        assert!(fp.avg_energy_scale() > 1.0);
    }

    #[test]
    fn star_hub_placement_short_links() {
        let t = Topology::star(16).unwrap();
        let fp = Floorplan::place(&t);
        // Hub placed first; average leaf distance bounded by grid diameter.
        assert!(fp.max_link_length() <= 2 * fp.grid);
        assert!(fp.total_wirelength() >= 15);
    }

    #[test]
    fn greedy_beats_random_for_ring() {
        // The BFS snake keeps ring neighbours adjacent: total wirelength
        // close to N (optimal) instead of O(N * grid).
        let t = Topology::ring(16).unwrap();
        let fp = Floorplan::place(&t);
        assert!(fp.total_wirelength() <= 16 + 2 * 4, "{}", fp.total_wirelength());
    }

    #[test]
    fn die_area() {
        let t = Topology::mesh(4, 4).unwrap();
        let fp = Floorplan::place(&t);
        assert_eq!(fp.grid, 4);
        assert!((fp.die_area_mm2(1.5) - 36.0).abs() < 1e-9);
    }

    #[test]
    fn every_link_costed_once() {
        for t in [
            Topology::mesh(3, 5).unwrap(),
            Topology::torus(4, 4).unwrap(),
            Topology::fattree(3).unwrap(),
        ] {
            let fp = Floorplan::place(&t);
            assert_eq!(fp.links.len(), t.links());
        }
    }
}
