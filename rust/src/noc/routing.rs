//! Routing functions: next-hop selection per topology.
//!
//! * Mesh/torus use dimension-order (XY) routing — deadlock-free without
//!   escape VCs (Dally & Towles); the torus variant picks the shorter
//!   wrap direction and relies on the second VC as the dateline escape
//!   channel (the simulator assigns VCs accordingly).
//! * Everything else uses table-based shortest-path next hops, precomputed
//!   by BFS from every destination (deterministic lowest-id tie-break so
//!   runs replay identically).

use super::topology::{NodeId, Topology, TopologyKind};

/// Sentinel in the output-port table for cur == dst or unreachable pairs.
const NO_PORT: u16 = u16::MAX;

/// XY direction indices into [`RouteTable::dir_ports`].
const DIR_XNEG: usize = 0;
const DIR_XPOS: usize = 1;
const DIR_YNEG: usize = 2;
const DIR_YPOS: usize = 3;

/// Precomputed routing.
///
/// Mesh/torus (the large-fabric topologies) use *computed* routing: the
/// output port towards a destination is dimension-order XY arithmetic
/// plus a tiny per-node direction→port cache (`dir_ports`, 8 bytes per
/// node). Irregular topologies keep the dense tables: `next[dst][cur]`
/// (BFS next hop) and a flat per-(cur, dst) output-port cache. The dense
/// tables are O(n²) — 167 MB for a 64x64 mesh — which is why mesh/torus
/// must not build them (ROADMAP: large-mesh route tables); computed
/// ports cost O(n) memory and one compare chain per lookup, and are
/// asserted route-for-route identical to the dense construction on 8x8
/// and 64x64 fabrics.
#[derive(Debug, Clone)]
pub struct RouteTable {
    /// BFS next-hop table (irregular topologies only; empty for
    /// mesh/torus).
    next: Vec<Vec<NodeId>>,
    /// out_ports[dst * nodes + cur] = output-port index at `cur` towards
    /// `dst` ([`NO_PORT`] on the diagonal and for unreachable pairs).
    /// Irregular topologies only; empty for mesh/torus.
    out_ports: Vec<u16>,
    /// Mesh/torus: per-node output port for each XY direction
    /// `[-x, +x, -y, +y]`; [`NO_PORT`] where the direction has no link
    /// (mesh boundary). Empty for irregular topologies.
    dir_ports: Vec<[u16; 4]>,
    nodes: usize,
    kind: TopologyKind,
}

impl RouteTable {
    pub fn build(topo: &Topology) -> Self {
        let n = topo.nodes();
        let kind = topo.kind();
        if matches!(kind, TopologyKind::Mesh { .. } | TopologyKind::Torus { .. }) {
            // Computed routing: only the per-node direction→port map is
            // materialized (one neighbor scan per node at build time).
            let mut dir_ports = vec![[NO_PORT; 4]; n];
            for (cur, ports) in dir_ports.iter_mut().enumerate() {
                for dir in 0..4 {
                    let Some(nxt) = dir_target(kind, cur, dir) else { continue };
                    let port = topo
                        .neighbors(cur)
                        .iter()
                        .position(|&(v, _)| v == nxt)
                        .expect("mesh/torus neighbor missing for XY direction");
                    debug_assert!(port < NO_PORT as usize);
                    ports[dir] = port as u16;
                }
            }
            return RouteTable {
                next: Vec::new(),
                out_ports: Vec::new(),
                dir_ports,
                nodes: n,
                kind,
            };
        }
        let mut next = vec![vec![0; n]; n];
        for (dst, row) in next.iter_mut().enumerate() {
            // BFS from dst; next hop towards dst = parent in BFS tree.
            let mut parent = vec![usize::MAX; n];
            let mut q = std::collections::VecDeque::new();
            parent[dst] = dst;
            q.push_back(dst);
            while let Some(u) = q.pop_front() {
                for &(v, _) in topo.neighbors(u) {
                    if parent[v] == usize::MAX {
                        parent[v] = u;
                        q.push_back(v);
                    }
                }
            }
            for cur in 0..n {
                row[cur] = if parent[cur] == usize::MAX { cur } else { parent[cur] };
            }
        }
        let mut table = RouteTable {
            next,
            out_ports: vec![NO_PORT; n * n],
            dir_ports: Vec::new(),
            nodes: n,
            kind,
        };
        for dst in 0..n {
            for cur in 0..n {
                if cur == dst {
                    continue;
                }
                let nxt = table.next_hop(cur, dst);
                if nxt == cur {
                    continue; // unreachable (disconnected custom graphs)
                }
                let port = topo
                    .neighbors(cur)
                    .iter()
                    .position(|&(v, _)| v == nxt)
                    .expect("route table returned non-neighbor");
                debug_assert!(port < NO_PORT as usize);
                table.out_ports[dst * n + cur] = port as u16;
            }
        }
        table
    }

    /// Output-port index at `cur` towards `dst` (`cur != dst`). O(1):
    /// XY arithmetic + per-node direction cache on mesh/torus, a dense
    /// table read otherwise; panics (via debug assert) for unroutable
    /// pairs.
    #[inline]
    pub fn out_port(&self, cur: NodeId, dst: NodeId) -> usize {
        debug_assert_ne!(cur, dst, "no output port towards self");
        let p = match self.kind {
            TopologyKind::Mesh { w, .. } => self.dir_ports[cur][mesh_dir(cur, dst, w)],
            TopologyKind::Torus { w, h } => self.dir_ports[cur][torus_dir(cur, dst, w, h)],
            _ => self.out_ports[dst * self.nodes + cur],
        };
        debug_assert_ne!(p, NO_PORT, "no route {cur} -> {dst}");
        p as usize
    }

    /// Next hop from `cur` towards `dst`. Dimension-order for mesh/torus,
    /// table lookup otherwise.
    pub fn next_hop(&self, cur: NodeId, dst: NodeId) -> NodeId {
        match self.kind {
            TopologyKind::Mesh { w, .. } => xy_mesh(cur, dst, w),
            TopologyKind::Torus { w, h } => xy_torus(cur, dst, w, h),
            _ => self.next[dst][cur],
        }
    }

    /// Hop count along the chosen route (for analytic estimates and the
    /// no-livelock property test).
    pub fn route_len(&self, src: NodeId, dst: NodeId) -> usize {
        let mut cur = src;
        let mut hops = 0;
        while cur != dst {
            let nxt = self.next_hop(cur, dst);
            assert_ne!(nxt, cur, "routing stuck at {cur} towards {dst}");
            cur = nxt;
            hops += 1;
            assert!(hops <= self.nodes, "routing loop {src}->{dst}");
        }
        hops
    }
}

/// Neighbor reached from `cur` in XY direction `dir`, or `None` when the
/// direction has no link (mesh boundary, or a 1-wide torus dimension).
/// For 2-wide torus dimensions both directions resolve to the same
/// neighbor (the constructor skips the duplicate wrap link), so both map
/// to the same port — exactly what dimension-order routing needs.
fn dir_target(kind: TopologyKind, cur: NodeId, dir: usize) -> Option<NodeId> {
    match kind {
        TopologyKind::Mesh { w, h } => {
            let (cx, cy) = (cur % w, cur / w);
            match dir {
                DIR_XNEG if cx > 0 => Some(cur - 1),
                DIR_XPOS if cx + 1 < w => Some(cur + 1),
                DIR_YNEG if cy > 0 => Some(cur - w),
                DIR_YPOS if cy + 1 < h => Some(cur + w),
                _ => None,
            }
        }
        TopologyKind::Torus { w, h } => {
            let (cx, cy) = (cur % w, cur / w);
            let t = match dir {
                DIR_XNEG => cy * w + (cx + w - 1) % w,
                DIR_XPOS => cy * w + (cx + 1) % w,
                DIR_YNEG => ((cy + h - 1) % h) * w + cx,
                DIR_YPOS => ((cy + 1) % h) * w + cx,
                _ => unreachable!(),
            };
            if t == cur {
                None // 1-wide dimension: no link in this direction
            } else {
                Some(t)
            }
        }
        _ => unreachable!("dir_target is mesh/torus-only"),
    }
}

/// XY direction taken by [`xy_mesh`] from `cur` towards `dst` — same
/// branch order, so the computed port always equals the port towards
/// `xy_mesh`'s next hop.
#[inline]
fn mesh_dir(cur: NodeId, dst: NodeId, w: usize) -> usize {
    let (cx, cy) = (cur % w, cur / w);
    let (dx, dy) = (dst % w, dst / w);
    if cx < dx {
        DIR_XPOS
    } else if cx > dx {
        DIR_XNEG
    } else if cy < dy {
        DIR_YPOS
    } else {
        debug_assert!(cy > dy, "no direction towards self");
        DIR_YNEG
    }
}

/// XY direction taken by [`xy_torus`] from `cur` towards `dst` (shorter
/// wrap, forward on ties — same tie-break as [`xy_torus`]).
#[inline]
fn torus_dir(cur: NodeId, dst: NodeId, w: usize, h: usize) -> usize {
    let (cx, cy) = (cur % w, cur / w);
    let (dx, dy) = (dst % w, dst / w);
    if cx != dx {
        let fwd = (dx + w - cx) % w;
        if fwd <= w - fwd {
            DIR_XPOS
        } else {
            DIR_XNEG
        }
    } else {
        debug_assert_ne!(cy, dy, "no direction towards self");
        let fwd = (dy + h - cy) % h;
        if fwd <= h - fwd {
            DIR_YPOS
        } else {
            DIR_YNEG
        }
    }
}

/// Dimension-order XY on a w-wide mesh: correct X first, then Y.
pub fn xy_mesh(cur: NodeId, dst: NodeId, w: usize) -> NodeId {
    let (cx, cy) = (cur % w, cur / w);
    let (dx, dy) = (dst % w, dst / w);
    if cx < dx {
        cur + 1
    } else if cx > dx {
        cur - 1
    } else if cy < dy {
        cur + w
    } else if cy > dy {
        cur - w
    } else {
        cur
    }
}

/// Dimension-order XY on a torus, taking the shorter wrap direction.
pub fn xy_torus(cur: NodeId, dst: NodeId, w: usize, h: usize) -> NodeId {
    let (cx, cy) = (cur % w, cur / w);
    let (dx, dy) = (dst % w, dst / w);
    if cx != dx {
        let fwd = (dx + w - cx) % w; // +x hops
        let nx = if fwd <= w - fwd { (cx + 1) % w } else { (cx + w - 1) % w };
        cy * w + nx
    } else if cy != dy {
        let fwd = (dy + h - cy) % h;
        let ny = if fwd <= h - fwd { (cy + 1) % h } else { (cy + h - 1) % h };
        ny * w + cx
    } else {
        cur
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::Topology;

    #[test]
    fn xy_mesh_goes_x_first() {
        // 4-wide mesh: 0 -> 10 (x=2,y=2): first +x, +x, then +y, +y.
        assert_eq!(xy_mesh(0, 10, 4), 1);
        assert_eq!(xy_mesh(1, 10, 4), 2);
        assert_eq!(xy_mesh(2, 10, 4), 6);
        assert_eq!(xy_mesh(6, 10, 4), 10);
        assert_eq!(xy_mesh(10, 10, 4), 10);
    }

    #[test]
    fn xy_torus_picks_short_wrap() {
        // 4x1 torus in x: 0 -> 3 is one wrap hop (-x).
        assert_eq!(xy_torus(0, 3, 4, 4), 3);
        // 0 -> 2 is two hops either way; forward preferred on tie.
        assert_eq!(xy_torus(0, 2, 4, 4), 1);
    }

    #[test]
    fn all_topologies_route_everywhere() {
        let topos = vec![
            Topology::mesh(4, 4).unwrap(),
            Topology::torus(4, 4).unwrap(),
            Topology::ring(9).unwrap(),
            Topology::star(8).unwrap(),
            Topology::fattree(3).unwrap(),
        ];
        for t in topos {
            let rt = RouteTable::build(&t);
            for s in 0..t.nodes() {
                let dist = t.distances(s);
                for d in 0..t.nodes() {
                    if s == d {
                        continue;
                    }
                    // route terminates and is shortest (for table + XY on
                    // these regular graphs).
                    let len = rt.route_len(s, d);
                    assert_eq!(len, dist[d], "{s}->{d} on {:?}", t.kind());
                }
            }
        }
    }

    #[test]
    fn out_port_cache_matches_next_hop() {
        let topos = vec![
            Topology::mesh(4, 4).unwrap(),
            Topology::torus(4, 4).unwrap(),
            Topology::ring(9).unwrap(),
            Topology::star(8).unwrap(),
            Topology::fattree(3).unwrap(),
        ];
        for t in topos {
            let rt = RouteTable::build(&t);
            for s in 0..t.nodes() {
                for d in 0..t.nodes() {
                    if s == d {
                        continue;
                    }
                    let port = rt.out_port(s, d);
                    assert_eq!(
                        t.neighbors(s)[port].0,
                        rt.next_hop(s, d),
                        "{s}->{d} on {:?}",
                        t.kind()
                    );
                }
            }
        }
    }

    /// Route-for-route parity of the computed mesh/torus ports against a
    /// per-pair neighbor scan (the construction the dense table used),
    /// on 8x8 and 64x64 fabrics (ROADMAP: large-mesh route tables).
    #[test]
    fn computed_ports_match_scan_on_8x8_and_64x64() {
        for (w, h) in [(8usize, 8usize), (64, 64)] {
            for t in [Topology::mesh(w, h).unwrap(), Topology::torus(w, h).unwrap()] {
                let rt = RouteTable::build(&t);
                for cur in 0..t.nodes() {
                    for dst in 0..t.nodes() {
                        if cur == dst {
                            continue;
                        }
                        let nxt = rt.next_hop(cur, dst);
                        let want = t
                            .neighbors(cur)
                            .iter()
                            .position(|&(v, _)| v == nxt)
                            .expect("XY next hop must be a neighbor");
                        assert_eq!(
                            rt.out_port(cur, dst),
                            want,
                            "{cur}->{dst} on {:?} {w}x{h}",
                            t.kind()
                        );
                    }
                }
            }
        }
    }

    /// Narrow torus dimensions (w or h in {1, 2}) skip duplicate/self
    /// wrap links; the direction cache must still resolve every pair.
    #[test]
    fn computed_ports_cover_narrow_torus_dims() {
        for (w, h) in [(2usize, 5usize), (5, 2), (2, 2), (1, 4), (4, 1)] {
            let t = Topology::torus(w, h).unwrap();
            if t.nodes() < 2 {
                continue;
            }
            let rt = RouteTable::build(&t);
            for cur in 0..t.nodes() {
                for dst in 0..t.nodes() {
                    if cur == dst {
                        continue;
                    }
                    let nxt = rt.next_hop(cur, dst);
                    let want = t
                        .neighbors(cur)
                        .iter()
                        .position(|&(v, _)| v == nxt)
                        .expect("XY next hop must be a neighbor");
                    assert_eq!(rt.out_port(cur, dst), want, "{cur}->{dst} {w}x{h}");
                }
            }
        }
    }

    #[test]
    fn next_hop_is_a_neighbor() {
        let t = Topology::fattree(2).unwrap();
        let rt = RouteTable::build(&t);
        for s in 0..t.nodes() {
            for d in 0..t.nodes() {
                if s == d {
                    continue;
                }
                let n = rt.next_hop(s, d);
                assert!(
                    t.neighbors(s).iter().any(|&(v, _)| v == n),
                    "{s}->{d} hop {n} not adjacent"
                );
            }
        }
    }
}
