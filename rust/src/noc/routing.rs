//! Routing functions: next-hop selection per topology.
//!
//! * Mesh/torus use dimension-order (XY) routing — deadlock-free without
//!   escape VCs (Dally & Towles); the torus variant picks the shorter
//!   wrap direction and relies on the second VC as the dateline escape
//!   channel (the simulator assigns VCs accordingly).
//! * Everything else uses table-based shortest-path next hops, precomputed
//!   by BFS from every destination (deterministic lowest-id tie-break so
//!   runs replay identically).

use super::topology::{NodeId, Topology, TopologyKind};

/// Sentinel in the output-port table for cur == dst or unreachable pairs.
const NO_PORT: u16 = u16::MAX;

/// Precomputed routing: `next[dst][cur]` = next hop from `cur` towards
/// `dst` (cur == dst maps to itself), plus a flat per-(cur, dst)
/// *output-port* cache so the simulator's inner loop is a single table
/// read — no per-flit XY arithmetic or neighbor-position scan.
#[derive(Debug, Clone)]
pub struct RouteTable {
    next: Vec<Vec<NodeId>>,
    /// out_ports[dst * nodes + cur] = output-port index at `cur` towards
    /// `dst` ([`NO_PORT`] on the diagonal and for unreachable pairs).
    out_ports: Vec<u16>,
    nodes: usize,
    kind: TopologyKind,
}

impl RouteTable {
    pub fn build(topo: &Topology) -> Self {
        let n = topo.nodes();
        let mut next = vec![vec![0; n]; n];
        for dst in 0..n {
            // BFS from dst; next hop towards dst = parent in BFS tree.
            let mut parent = vec![usize::MAX; n];
            let mut q = std::collections::VecDeque::new();
            parent[dst] = dst;
            q.push_back(dst);
            while let Some(u) = q.pop_front() {
                for &(v, _) in topo.neighbors(u) {
                    if parent[v] == usize::MAX {
                        parent[v] = u;
                        q.push_back(v);
                    }
                }
            }
            for cur in 0..n {
                next[dst][cur] = if parent[cur] == usize::MAX { cur } else { parent[cur] };
            }
        }
        let mut table = RouteTable { next, out_ports: vec![NO_PORT; n * n], nodes: n, kind: topo.kind() };
        for dst in 0..n {
            for cur in 0..n {
                if cur == dst {
                    continue;
                }
                let nxt = table.next_hop(cur, dst);
                if nxt == cur {
                    continue; // unreachable (disconnected custom graphs)
                }
                let port = topo
                    .neighbors(cur)
                    .iter()
                    .position(|&(v, _)| v == nxt)
                    .expect("route table returned non-neighbor");
                debug_assert!(port < NO_PORT as usize);
                table.out_ports[dst * n + cur] = port as u16;
            }
        }
        table
    }

    /// Output-port index at `cur` towards `dst` (`cur != dst`). O(1)
    /// table lookup; panics (via debug assert) for unroutable pairs.
    #[inline]
    pub fn out_port(&self, cur: NodeId, dst: NodeId) -> usize {
        debug_assert_ne!(cur, dst, "no output port towards self");
        let p = self.out_ports[dst * self.nodes + cur];
        debug_assert_ne!(p, NO_PORT, "no route {cur} -> {dst}");
        p as usize
    }

    /// Next hop from `cur` towards `dst`. Dimension-order for mesh/torus,
    /// table lookup otherwise.
    pub fn next_hop(&self, cur: NodeId, dst: NodeId) -> NodeId {
        match self.kind {
            TopologyKind::Mesh { w, .. } => xy_mesh(cur, dst, w),
            TopologyKind::Torus { w, h } => xy_torus(cur, dst, w, h),
            _ => self.next[dst][cur],
        }
    }

    /// Hop count along the chosen route (for analytic estimates and the
    /// no-livelock property test).
    pub fn route_len(&self, src: NodeId, dst: NodeId) -> usize {
        let mut cur = src;
        let mut hops = 0;
        while cur != dst {
            let nxt = self.next_hop(cur, dst);
            assert_ne!(nxt, cur, "routing stuck at {cur} towards {dst}");
            cur = nxt;
            hops += 1;
            assert!(hops <= self.next.len(), "routing loop {src}->{dst}");
        }
        hops
    }
}

/// Dimension-order XY on a w-wide mesh: correct X first, then Y.
pub fn xy_mesh(cur: NodeId, dst: NodeId, w: usize) -> NodeId {
    let (cx, cy) = (cur % w, cur / w);
    let (dx, dy) = (dst % w, dst / w);
    if cx < dx {
        cur + 1
    } else if cx > dx {
        cur - 1
    } else if cy < dy {
        cur + w
    } else if cy > dy {
        cur - w
    } else {
        cur
    }
}

/// Dimension-order XY on a torus, taking the shorter wrap direction.
pub fn xy_torus(cur: NodeId, dst: NodeId, w: usize, h: usize) -> NodeId {
    let (cx, cy) = (cur % w, cur / w);
    let (dx, dy) = (dst % w, dst / w);
    if cx != dx {
        let fwd = (dx + w - cx) % w; // +x hops
        let nx = if fwd <= w - fwd { (cx + 1) % w } else { (cx + w - 1) % w };
        cy * w + nx
    } else if cy != dy {
        let fwd = (dy + h - cy) % h;
        let ny = if fwd <= h - fwd { (cy + 1) % h } else { (cy + h - 1) % h };
        ny * w + cx
    } else {
        cur
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::Topology;

    #[test]
    fn xy_mesh_goes_x_first() {
        // 4-wide mesh: 0 -> 10 (x=2,y=2): first +x, +x, then +y, +y.
        assert_eq!(xy_mesh(0, 10, 4), 1);
        assert_eq!(xy_mesh(1, 10, 4), 2);
        assert_eq!(xy_mesh(2, 10, 4), 6);
        assert_eq!(xy_mesh(6, 10, 4), 10);
        assert_eq!(xy_mesh(10, 10, 4), 10);
    }

    #[test]
    fn xy_torus_picks_short_wrap() {
        // 4x1 torus in x: 0 -> 3 is one wrap hop (-x).
        assert_eq!(xy_torus(0, 3, 4, 4), 3);
        // 0 -> 2 is two hops either way; forward preferred on tie.
        assert_eq!(xy_torus(0, 2, 4, 4), 1);
    }

    #[test]
    fn all_topologies_route_everywhere() {
        let topos = vec![
            Topology::mesh(4, 4).unwrap(),
            Topology::torus(4, 4).unwrap(),
            Topology::ring(9).unwrap(),
            Topology::star(8).unwrap(),
            Topology::fattree(3).unwrap(),
        ];
        for t in topos {
            let rt = RouteTable::build(&t);
            for s in 0..t.nodes() {
                let dist = t.distances(s);
                for d in 0..t.nodes() {
                    if s == d {
                        continue;
                    }
                    // route terminates and is shortest (for table + XY on
                    // these regular graphs).
                    let len = rt.route_len(s, d);
                    assert_eq!(len, dist[d], "{s}->{d} on {:?}", t.kind());
                }
            }
        }
    }

    #[test]
    fn out_port_cache_matches_next_hop() {
        let topos = vec![
            Topology::mesh(4, 4).unwrap(),
            Topology::torus(4, 4).unwrap(),
            Topology::ring(9).unwrap(),
            Topology::star(8).unwrap(),
            Topology::fattree(3).unwrap(),
        ];
        for t in topos {
            let rt = RouteTable::build(&t);
            for s in 0..t.nodes() {
                for d in 0..t.nodes() {
                    if s == d {
                        continue;
                    }
                    let port = rt.out_port(s, d);
                    assert_eq!(
                        t.neighbors(s)[port].0,
                        rt.next_hop(s, d),
                        "{s}->{d} on {:?}",
                        t.kind()
                    );
                }
            }
        }
    }

    #[test]
    fn next_hop_is_a_neighbor() {
        let t = Topology::fattree(2).unwrap();
        let rt = RouteTable::build(&t);
        for s in 0..t.nodes() {
            for d in 0..t.nodes() {
                if s == d {
                    continue;
                }
                let n = rt.next_hop(s, d);
                assert!(
                    t.neighbors(s).iter().any(|&(v, _)| v == n),
                    "{s}->{d} hop {n} not adjacent"
                );
            }
        }
    }
}
