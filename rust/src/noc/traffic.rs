//! Synthetic traffic generators for the NoC studies (E2): uniform random,
//! hotspot, transpose, nearest-neighbour and a Poisson-ish open-loop
//! injector used for saturation sweeps.

use super::topology::NodeId;
use crate::sim::{Cycle, Rng};

/// One injection request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Injection {
    pub at: Cycle,
    pub src: NodeId,
    pub dst: NodeId,
    pub bytes: usize,
}

/// Traffic pattern kinds used in the scaling study.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pattern {
    /// Uniform random destinations.
    Uniform,
    /// Fraction `hot_permille`/1000 of packets target node 0.
    Hotspot { hot_permille: u32 },
    /// Bit-transpose on a w×w mesh: (x,y) -> (y,x).
    Transpose { w: usize },
    /// Ring-style nearest neighbour (n -> n+1 mod N).
    Neighbor,
}

/// Open-loop generator: every node injects one `bytes`-sized packet per
/// `1/rate` cycles on average (Bernoulli per cycle), for `cycles` cycles.
pub fn generate(
    pattern: Pattern,
    nodes: usize,
    rate: f64,
    bytes: usize,
    cycles: Cycle,
    rng: &mut Rng,
) -> Vec<Injection> {
    assert!(nodes >= 2, "need at least two nodes");
    let mut out = Vec::new();
    for t in 0..cycles {
        for src in 0..nodes {
            if !rng.chance(rate) {
                continue;
            }
            let dst = pick_dst(pattern, src, nodes, rng);
            if dst != src {
                out.push(Injection { at: t, src, dst, bytes });
            }
        }
    }
    out
}

fn pick_dst(pattern: Pattern, src: NodeId, nodes: usize, rng: &mut Rng) -> NodeId {
    match pattern {
        Pattern::Uniform => {
            let mut d = rng.below(nodes);
            while d == src {
                d = rng.below(nodes);
            }
            d
        }
        Pattern::Hotspot { hot_permille } => {
            if rng.below(1000) < hot_permille as usize && src != 0 {
                0
            } else {
                let mut d = rng.below(nodes);
                while d == src {
                    d = rng.below(nodes);
                }
                d
            }
        }
        Pattern::Transpose { w } => {
            let (x, y) = (src % w, src / w);
            let d = x * w + y;
            if d == src || d >= nodes {
                (src + 1) % nodes
            } else {
                d
            }
        }
        Pattern::Neighbor => (src + 1) % nodes,
    }
}

/// Drive a [`super::NocSim`] with an injection schedule, stepping the
/// simulator as time advances, then drain. Returns the final report.
pub fn drive(
    sim: &mut super::NocSim,
    mut schedule: Vec<Injection>,
    max_cycles: Cycle,
) -> super::SimReport {
    schedule.sort_by_key(|i| i.at);
    let mut next = 0;
    while next < schedule.len() && sim.now() < max_cycles {
        while next < schedule.len() && schedule[next].at <= sim.now() {
            let inj = schedule[next];
            sim.inject(inj.src, inj.dst, inj.bytes);
            next += 1;
        }
        sim.step();
    }
    sim.run_to_drain(max_cycles)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::{NocParams, NocSim, Topology};

    #[test]
    fn generate_respects_rate_roughly() {
        let mut rng = Rng::new(1);
        let inj = generate(Pattern::Uniform, 16, 0.1, 32, 1000, &mut rng);
        let expect = 16.0 * 0.1 * 1000.0;
        assert!((inj.len() as f64 - expect).abs() < expect * 0.2, "{}", inj.len());
        assert!(inj.iter().all(|i| i.src != i.dst));
    }

    #[test]
    fn hotspot_concentrates_on_node0() {
        let mut rng = Rng::new(2);
        let inj = generate(Pattern::Hotspot { hot_permille: 500 }, 16, 0.2, 32, 500, &mut rng);
        let to0 = inj.iter().filter(|i| i.dst == 0).count();
        assert!(to0 as f64 > inj.len() as f64 * 0.3, "{to0}/{}", inj.len());
    }

    #[test]
    fn transpose_is_involution() {
        let mut rng = Rng::new(3);
        for src in 0..16 {
            let d = pick_dst(Pattern::Transpose { w: 4 }, src, 16, &mut rng);
            if d != (src % 4) * 4 + src / 4 {
                // diagonal fallback
                assert_eq!(d, (src + 1) % 16);
            } else if src != d {
                let back = pick_dst(Pattern::Transpose { w: 4 }, d, 16, &mut rng);
                assert_eq!(back, src);
            }
        }
    }

    #[test]
    fn drive_delivers_everything_at_low_load() {
        let mut sim = NocSim::new(Topology::mesh(4, 4).unwrap(), NocParams::default());
        let mut rng = Rng::new(4);
        let inj = generate(Pattern::Uniform, 16, 0.02, 64, 2000, &mut rng);
        let n = inj.len();
        let rep = drive(&mut sim, inj, 1_000_000);
        assert_eq!(rep.delivered, n);
        assert_eq!(rep.in_flight, 0);
    }

    #[test]
    fn saturation_latency_grows_with_load() {
        let lat_at = |rate: f64| {
            let mut sim = NocSim::new(Topology::mesh(4, 4).unwrap(), NocParams::default());
            let mut rng = Rng::new(5);
            let inj = generate(Pattern::Uniform, 16, rate, 64, 2000, &mut rng);
            let rep = drive(&mut sim, inj, 2_000_000);
            rep.avg_latency
        };
        let low = lat_at(0.01);
        let high = lat_at(0.30);
        assert!(high > low * 1.5, "low {low} high {high}");
    }
}
