//! Reference NoC simulator: the pre-event-wheel implementation, retained
//! verbatim for differential testing and as the in-repo performance
//! baseline.
//!
//! [`RefNocSim`] models exactly the same microarchitecture as
//! [`super::NocSim`] — same allocation, traversal and credit rules, same
//! fixed iteration order — but with the original data layout: per-router
//! `Vec<Vec<VecDeque<Flit>>>` buffers, unsorted arrival/credit `Vec`s
//! drained and reallocated every cycle, and per-flit linear neighbor
//! scans for routing and reverse ports. The golden tests
//! (`tests/noc_golden.rs`) assert that both simulators produce
//! bit-identical [`SimReport`]s and per-packet timelines on fixed seeds;
//! `benches/bench_noc.rs` runs both on the same workload to report the
//! hot-loop speedup.
//!
//! Do not optimize this module — its value is being the slow, obviously
//! faithful model.

use std::collections::VecDeque;

use super::router::{Flit, FlitKind};
use super::routing::RouteTable;
use super::sim::{NocParams, PacketStats, SimReport};
use super::topology::{NodeId, Topology};
use super::traffic::Injection;
use crate::metrics::{Category, Metrics};
use crate::sim::Cycle;

/// Drive a [`RefNocSim`] with an injection schedule, stepping as time
/// advances, then drain — the same contract as [`super::traffic::drive`]
/// (which only accepts the production simulator), so differential tests
/// and benches feed both simulators identical timelines without
/// hand-copied drive loops.
pub fn drive(sim: &mut RefNocSim, mut schedule: Vec<Injection>, max_cycles: Cycle) -> SimReport {
    schedule.sort_by_key(|i| i.at);
    let mut next = 0;
    while next < schedule.len() && sim.now() < max_cycles {
        while next < schedule.len() && schedule[next].at <= sim.now() {
            let inj = schedule[next];
            sim.inject(inj.src, inj.dst, inj.bytes);
            next += 1;
        }
        sim.step();
    }
    sim.run_to_drain(max_cycles)
}

/// Per-router buffer state in the original nested layout.
struct RefRouter {
    /// in_buf[port][vc] — input queues. Port 0..deg are neighbor links in
    /// `Topology::neighbors` order; port deg is the local injection port.
    in_buf: Vec<Vec<VecDeque<Flit>>>,
    /// out_owner[port][vc] = Some((in_port, in_vc)) while a packet holds
    /// the output.
    out_owner: Vec<Vec<Option<(usize, usize)>>>,
    /// credits[port][vc] = free buffer slots at the downstream input.
    credits: Vec<Vec<usize>>,
    /// Round-robin arbitration pointer per output port.
    rr: Vec<usize>,
}

impl RefRouter {
    fn new(ports_in: usize, ports_out: usize, vcs: usize, buf_flits: usize) -> Self {
        RefRouter {
            in_buf: (0..ports_in)
                .map(|_| (0..vcs).map(|_| VecDeque::new()).collect())
                .collect(),
            out_owner: vec![vec![None; vcs]; ports_out],
            credits: vec![vec![buf_flits; vcs]; ports_out],
            rr: vec![0; ports_out],
        }
    }

    fn occupancy(&self) -> usize {
        self.in_buf.iter().flat_map(|p| p.iter().map(|q| q.len())).sum()
    }
}

struct Arrival {
    at: Cycle,
    node: NodeId,
    port: usize,
    flit: Flit,
}

struct CreditReturn {
    at: Cycle,
    node: NodeId,
    out_port: usize,
    vc: usize,
}

/// The reference simulator (original data layout; see module docs).
pub struct RefNocSim {
    topo: Topology,
    routes: RouteTable,
    params: NocParams,
    routers: Vec<RefRouter>,
    inject_q: Vec<VecDeque<Flit>>,
    arrivals: Vec<Arrival>,
    credit_returns: Vec<CreditReturn>,
    packets: Vec<PacketStats>,
    now: Cycle,
    flit_hops: u64,
    delivered: usize,
}

impl RefNocSim {
    pub fn new(topo: Topology, params: NocParams) -> Self {
        let routes = RouteTable::build(&topo);
        let routers = (0..topo.nodes())
            .map(|n| {
                let deg = topo.degree(n);
                RefRouter::new(deg + 1, deg + 1, params.vcs, params.buf_flits)
            })
            .collect();
        let inject_q = (0..topo.nodes()).map(|_| VecDeque::new()).collect();
        RefNocSim {
            topo,
            routes,
            params,
            routers,
            inject_q,
            arrivals: Vec::new(),
            credit_returns: Vec::new(),
            packets: Vec::new(),
            now: 0,
            flit_hops: 0,
            delivered: 0,
        }
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    pub fn now(&self) -> Cycle {
        self.now
    }

    pub fn packets(&self) -> &[PacketStats] {
        &self.packets
    }

    /// Queue a packet for injection at the current cycle. Returns its id.
    pub fn inject(&mut self, src: NodeId, dst: NodeId, bytes: usize) -> usize {
        assert!(src < self.topo.nodes() && dst < self.topo.nodes());
        assert_ne!(src, dst, "self-traffic is not modelled");
        let id = self.packets.len();
        let nflits = bytes.div_ceil(self.params.flit_bytes).max(1);
        let vc = id % self.params.vcs;
        for i in 0..nflits {
            let kind = if i + 1 == nflits {
                FlitKind::Tail
            } else if i == 0 {
                FlitKind::Head
            } else {
                FlitKind::Body
            };
            self.inject_q[src].push_back(Flit {
                packet: id,
                kind,
                is_head: i == 0,
                dst,
                vc,
            });
        }
        self.packets.push(PacketStats {
            src,
            dst,
            flits: nflits,
            injected_at: self.now,
            ejected_at: None,
            hops: self.routes.route_len(src, dst),
        });
        id
    }

    /// Input-port index at `to` for the link arriving from `from`
    /// (original linear scan).
    fn in_port(&self, to: NodeId, from: NodeId) -> usize {
        self.topo
            .neighbors(to)
            .iter()
            .position(|&(v, _)| v == from)
            .expect("link endpoints inconsistent")
    }

    /// Output port at `n` towards `dst` (original linear scan; deg =
    /// ejection if dst == n).
    fn route_port(&self, n: NodeId, dst: NodeId, deg: usize) -> usize {
        if dst == n {
            return deg;
        }
        let next = self.routes.next_hop(n, dst);
        self.topo
            .neighbors(n)
            .iter()
            .position(|&(v, _)| v == next)
            .expect("route table returned non-neighbor")
    }

    /// Advance one cycle (original double-buffered step).
    pub fn step(&mut self) {
        let nodes = self.topo.nodes();
        let vcs = self.params.vcs;

        // 1. Local injection.
        for n in 0..nodes {
            let local = self.topo.degree(n);
            while let Some(&flit) = self.inject_q[n].front() {
                let buf = &mut self.routers[n].in_buf[local][flit.vc];
                if buf.len() >= self.params.buf_flits {
                    break;
                }
                buf.push_back(self.inject_q[n].pop_front().unwrap());
            }
        }

        // 2. Switch allocation + traversal, double-buffered.
        let mut new_arrivals: Vec<Arrival> = Vec::new();
        let mut new_credits: Vec<CreditReturn> = Vec::new();
        for n in 0..nodes {
            let deg = self.topo.degree(n);
            let ports_in = deg + 1;
            let mut input_busy = vec![false; ports_in];
            for p_out in 0..=deg {
                // 2a. VC allocation.
                for p_in in 0..ports_in {
                    for vc in 0..vcs {
                        let Some(&flit) = self.routers[n].in_buf[p_in][vc].front() else {
                            continue;
                        };
                        if !flit.is_head {
                            continue;
                        }
                        let want = self.route_port(n, flit.dst, deg);
                        if want != p_out {
                            continue;
                        }
                        if self.routers[n].out_owner[p_out][vc].is_none() {
                            self.routers[n].out_owner[p_out][vc] = Some((p_in, vc));
                        }
                    }
                }
                // 2b. Switch traversal.
                let rr0 = self.routers[n].rr[p_out];
                for k in 0..vcs {
                    let vc = (rr0 + k) % vcs;
                    let Some((p_in, in_vc)) = self.routers[n].out_owner[p_out][vc] else {
                        continue;
                    };
                    if input_busy[p_in] {
                        continue;
                    }
                    let Some(&flit) = self.routers[n].in_buf[p_in][in_vc].front() else {
                        continue;
                    };
                    let owner_ok = {
                        let want = if flit.dst == n {
                            deg
                        } else {
                            self.route_port(n, flit.dst, deg)
                        };
                        want == p_out
                    };
                    if !owner_ok {
                        continue;
                    }
                    let is_ejection = p_out == deg;
                    if !is_ejection && self.routers[n].credits[p_out][vc] == 0 {
                        continue;
                    }
                    // Commit the move.
                    let flit = self.routers[n].in_buf[p_in][in_vc].pop_front().unwrap();
                    input_busy[p_in] = true;
                    self.routers[n].rr[p_out] = (vc + 1) % vcs;
                    if flit.kind == FlitKind::Tail {
                        self.routers[n].out_owner[p_out][vc] = None;
                    }
                    if p_in < deg {
                        let (up, _) = self.topo.neighbors(n)[p_in];
                        let up_out_port = self.in_port(up, n);
                        new_credits.push(CreditReturn {
                            at: self.now + 1,
                            node: up,
                            out_port: up_out_port,
                            vc: in_vc,
                        });
                    }
                    if is_ejection {
                        if flit.kind == FlitKind::Tail {
                            let p = &mut self.packets[flit.packet];
                            p.ejected_at = Some(self.now + 1);
                            self.delivered += 1;
                        }
                    } else {
                        let (next, _) = self.topo.neighbors(n)[p_out];
                        let dest_port = self.in_port(next, n);
                        self.routers[n].credits[p_out][vc] -= 1;
                        self.flit_hops += 1;
                        new_arrivals.push(Arrival {
                            at: self.now + self.params.router_latency,
                            node: next,
                            port: dest_port,
                            flit,
                        });
                    }
                }
            }
        }

        // 3. Apply arrivals whose time has come (including older ones).
        self.arrivals.extend(new_arrivals);
        self.credit_returns.extend(new_credits);
        let now_next = self.now + 1;
        let mut rest = Vec::with_capacity(self.arrivals.len());
        for a in self.arrivals.drain(..) {
            if a.at <= now_next {
                self.routers[a.node].in_buf[a.port][a.flit.vc].push_back(a.flit);
            } else {
                rest.push(a);
            }
        }
        self.arrivals = rest;
        let mut rest = Vec::with_capacity(self.credit_returns.len());
        for c in self.credit_returns.drain(..) {
            if c.at <= now_next {
                self.routers[c.node].credits[c.out_port][c.vc] += 1;
            } else {
                rest.push(c);
            }
        }
        self.credit_returns = rest;

        self.now = now_next;
    }

    /// True when no flits remain anywhere.
    pub fn drained(&self) -> bool {
        self.inject_q.iter().all(VecDeque::is_empty)
            && self.arrivals.is_empty()
            && self.routers.iter().all(|r| r.occupancy() == 0)
    }

    /// Run until drained or `max_cycles`, then report.
    pub fn run_to_drain(&mut self, max_cycles: Cycle) -> SimReport {
        while !self.drained() && self.now < max_cycles {
            self.step();
        }
        self.report()
    }

    /// Run exactly `cycles` more cycles.
    pub fn run_for(&mut self, cycles: Cycle) {
        for _ in 0..cycles {
            self.step();
        }
    }

    pub fn report(&self) -> SimReport {
        let mut lats: Vec<u64> = self
            .packets
            .iter()
            .filter_map(|p| p.ejected_at.map(|e| e - p.injected_at))
            .collect();
        lats.sort_unstable();
        let avg = if lats.is_empty() {
            0.0
        } else {
            lats.iter().sum::<u64>() as f64 / lats.len() as f64
        };
        let p99 = if lats.is_empty() {
            0.0
        } else {
            lats[(lats.len() - 1).min(lats.len() * 99 / 100)] as f64
        };
        let mut metrics = Metrics::new();
        metrics.cycles = self.now;
        metrics.bytes_moved = self.flit_hops * self.params.flit_bytes as u64;
        metrics.add_energy(
            Category::Noc,
            self.flit_hops as f64 * self.params.flit_bytes as f64 * 8.0
                * self.params.hop_energy_pj_per_bit,
        );
        let delivered_flits: usize = self
            .packets
            .iter()
            .filter(|p| p.ejected_at.is_some())
            .map(|p| p.flits)
            .sum();
        SimReport {
            cycles: self.now,
            delivered: self.delivered,
            in_flight: self.packets.len() - self.delivered,
            avg_latency: avg,
            p99_latency: p99,
            flit_hops: self.flit_hops,
            throughput: if self.now == 0 {
                0.0
            } else {
                delivered_flits as f64 / self.now as f64 / self.topo.nodes() as f64
            },
            metrics,
        }
    }
}
