//! NoC topologies: regular constructors plus arbitrary low-radix graphs.

use anyhow::{bail, ensure};

use crate::Result;

/// Node index into a [`Topology`].
pub type NodeId = usize;

/// Which constructor built the topology (used by routing selection and by
/// the DSE reports).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TopologyKind {
    Mesh { w: usize, h: usize },
    Torus { w: usize, h: usize },
    Ring,
    Star,
    FatTree { down: usize },
    Custom,
}

/// An undirected multigraph of routers. Links are stored once per
/// direction (adjacency lists), so every physical link appears as two
/// directed edges with a shared link id.
#[derive(Debug, Clone)]
pub struct Topology {
    kind: TopologyKind,
    nodes: usize,
    /// adj[n] = (neighbor, link_id), sorted by neighbor.
    adj: Vec<Vec<(NodeId, usize)>>,
    /// rev[n][p] = position of `n` in `adj[v]` where `v = adj[n][p].0`:
    /// the input-port index at the far end of each outgoing link,
    /// precomputed so the simulator's per-flit lookups are O(1) table
    /// reads instead of linear neighbor scans.
    rev: Vec<Vec<usize>>,
    links: usize,
}

impl Topology {
    /// Build from an undirected edge list.
    pub fn custom(nodes: usize, edges: &[(NodeId, NodeId)]) -> Result<Self> {
        Self::build(TopologyKind::Custom, nodes, edges)
    }

    fn build(kind: TopologyKind, nodes: usize, edges: &[(NodeId, NodeId)]) -> Result<Self> {
        ensure!(nodes > 0, "topology needs at least one node");
        let mut adj = vec![Vec::new(); nodes];
        for (lid, &(a, b)) in edges.iter().enumerate() {
            ensure!(a < nodes && b < nodes, "edge ({a},{b}) out of range");
            ensure!(a != b, "self-loop on node {a}");
            if adj[a].iter().any(|&(n, _)| n == b) {
                bail!("duplicate edge ({a},{b})");
            }
            adj[a].push((b, lid));
            adj[b].push((a, lid));
        }
        for l in &mut adj {
            l.sort_unstable();
        }
        let rev = (0..nodes)
            .map(|n| {
                adj[n]
                    .iter()
                    .map(|&(v, _)| {
                        adj[v]
                            .iter()
                            .position(|&(u, _)| u == n)
                            .expect("adjacency lists are symmetric by construction")
                    })
                    .collect()
            })
            .collect();
        Ok(Topology { kind, nodes, adj, rev, links: edges.len() })
    }

    /// w×h 2-D mesh (node id = y*w + x).
    pub fn mesh(w: usize, h: usize) -> Result<Self> {
        ensure!(w > 0 && h > 0, "mesh dims must be nonzero");
        let mut edges = Vec::new();
        for y in 0..h {
            for x in 0..w {
                let n = y * w + x;
                if x + 1 < w {
                    edges.push((n, n + 1));
                }
                if y + 1 < h {
                    edges.push((n, n + w));
                }
            }
        }
        Self::build(TopologyKind::Mesh { w, h }, w * h, &edges)
    }

    /// w×h 2-D torus (wrap-around mesh). Wrap links are skipped where they
    /// would duplicate a mesh link (w or h == 2) or self-loop (w or h == 1).
    pub fn torus(w: usize, h: usize) -> Result<Self> {
        ensure!(w > 0 && h > 0, "torus dims must be nonzero");
        let mut edges = Vec::new();
        for y in 0..h {
            for x in 0..w {
                let n = y * w + x;
                if x + 1 < w {
                    edges.push((n, n + 1));
                } else if w > 2 {
                    edges.push((n, y * w));
                }
                if y + 1 < h {
                    edges.push((n, n + w));
                } else if h > 2 {
                    edges.push((n, x));
                }
            }
        }
        Self::build(TopologyKind::Torus { w, h }, w * h, &edges)
    }

    /// n-node ring.
    pub fn ring(n: usize) -> Result<Self> {
        ensure!(n >= 3, "ring needs >= 3 nodes");
        let edges: Vec<_> = (0..n).map(|i| (i, (i + 1) % n)).collect();
        Self::build(TopologyKind::Ring, n, &edges)
    }

    /// Star: node 0 is the hub, 1..n are leaves.
    pub fn star(n: usize) -> Result<Self> {
        ensure!(n >= 2, "star needs >= 2 nodes");
        let edges: Vec<_> = (1..n).map(|i| (0, i)).collect();
        Self::build(TopologyKind::Star, n, &edges)
    }

    /// Two-level fat tree: `down*down` leaves, `down` aggregation switches,
    /// one root; leaves are nodes `0..down*down` (the CU-facing ids).
    pub fn fattree(down: usize) -> Result<Self> {
        ensure!(down >= 2, "fattree needs down >= 2");
        let leaves = down * down;
        let aggs = down;
        let nodes = leaves + aggs + 1;
        let root = leaves + aggs;
        let mut edges = Vec::new();
        for a in 0..aggs {
            for l in 0..down {
                edges.push((a * down + l, leaves + a));
            }
            edges.push((leaves + a, root));
        }
        Self::build(TopologyKind::FatTree { down }, nodes, &edges)
    }

    /// Build by config name ("mesh", "torus", "ring", "star", "fattree").
    pub fn from_config(cfg: &crate::config::NocConfig) -> Result<Self> {
        match cfg.topology.as_str() {
            "mesh" => Self::mesh(cfg.width, cfg.height),
            "torus" => Self::torus(cfg.width, cfg.height),
            "ring" => Self::ring(cfg.width * cfg.height),
            "star" => Self::star(cfg.width * cfg.height),
            "fattree" => Self::fattree(cfg.width),
            other => bail!("unknown topology {other:?}"),
        }
    }

    pub fn kind(&self) -> TopologyKind {
        self.kind
    }

    pub fn nodes(&self) -> usize {
        self.nodes
    }

    pub fn links(&self) -> usize {
        self.links
    }

    /// Neighbors of `n` with their link ids.
    pub fn neighbors(&self, n: NodeId) -> &[(NodeId, usize)] {
        &self.adj[n]
    }

    /// Neighbor reached from `n` through its `port`-th link.
    #[inline]
    pub fn neighbor(&self, n: NodeId, port: usize) -> NodeId {
        self.adj[n][port].0
    }

    /// Input-port index at the far end of `n`'s `port`-th link: the
    /// position of `n` in that neighbor's adjacency list. O(1) — the
    /// reverse-port map is precomputed at build time.
    #[inline]
    pub fn reverse_port(&self, n: NodeId, port: usize) -> usize {
        self.rev[n][port]
    }

    /// Router radix (degree) of `n`, excluding the local port.
    pub fn degree(&self, n: NodeId) -> usize {
        self.adj[n].len()
    }

    pub fn max_degree(&self) -> usize {
        (0..self.nodes).map(|n| self.degree(n)).max().unwrap_or(0)
    }

    /// BFS hop distances from `src` (usize::MAX if unreachable).
    pub fn distances(&self, src: NodeId) -> Vec<usize> {
        let mut dist = vec![usize::MAX; self.nodes];
        let mut q = std::collections::VecDeque::new();
        dist[src] = 0;
        q.push_back(src);
        while let Some(u) = q.pop_front() {
            for &(v, _) in &self.adj[u] {
                if dist[v] == usize::MAX {
                    dist[v] = dist[u] + 1;
                    q.push_back(v);
                }
            }
        }
        dist
    }

    pub fn is_connected(&self) -> bool {
        self.distances(0).iter().all(|&d| d != usize::MAX)
    }

    /// Longest shortest path.
    pub fn diameter(&self) -> usize {
        (0..self.nodes)
            .map(|s| self.distances(s).into_iter().max().unwrap_or(0))
            .max()
            .unwrap_or(0)
    }

    /// Mean hop distance over ordered pairs (s != d).
    pub fn avg_distance(&self) -> f64 {
        if self.nodes < 2 {
            return 0.0;
        }
        let mut total = 0usize;
        for s in 0..self.nodes {
            total += self.distances(s).iter().sum::<usize>();
        }
        total as f64 / (self.nodes * (self.nodes - 1)) as f64
    }

    /// Bisection width estimate: links cut by splitting node ids in half.
    /// Exact for the regular constructors' natural orderings; a lower
    /// bound style heuristic for custom graphs (documented in DESIGN.md).
    pub fn bisection_links(&self) -> usize {
        let half = self.nodes / 2;
        let mut cut = 0;
        for a in 0..self.nodes {
            for &(b, _) in &self.adj[a] {
                if a < b && (a < half) != (b < half) {
                    cut += 1;
                }
            }
        }
        cut
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mesh_structure() {
        let t = Topology::mesh(4, 3).unwrap();
        assert_eq!(t.nodes(), 12);
        assert_eq!(t.links(), 3 * 3 + 4 * 2); // horizontal + vertical
        assert_eq!(t.degree(0), 2); // corner
        assert_eq!(t.degree(1), 3); // edge
        assert_eq!(t.degree(5), 4); // interior
        assert!(t.is_connected());
        assert_eq!(t.diameter(), 3 + 2);
    }

    #[test]
    fn torus_is_regular() {
        let t = Topology::torus(4, 4).unwrap();
        assert_eq!(t.nodes(), 16);
        assert_eq!(t.links(), 32);
        for n in 0..16 {
            assert_eq!(t.degree(n), 4);
        }
        assert_eq!(t.diameter(), 4); // 2 + 2
    }

    #[test]
    fn torus_small_dims_no_duplicate_links() {
        let t = Topology::torus(2, 2).unwrap();
        assert_eq!(t.links(), 4); // same as mesh(2,2)
        let t = Topology::torus(1, 3).unwrap();
        assert!(t.is_connected());
    }

    #[test]
    fn ring_and_star() {
        let r = Topology::ring(8).unwrap();
        assert_eq!(r.diameter(), 4);
        assert_eq!(r.links(), 8);
        let s = Topology::star(9).unwrap();
        assert_eq!(s.diameter(), 2);
        assert_eq!(s.degree(0), 8);
        assert_eq!(s.max_degree(), 8);
    }

    #[test]
    fn fattree_structure() {
        let t = Topology::fattree(3).unwrap();
        assert_eq!(t.nodes(), 9 + 3 + 1);
        assert!(t.is_connected());
        // leaf -> agg -> root -> agg -> leaf
        assert_eq!(t.diameter(), 4);
    }

    #[test]
    fn torus_beats_mesh_on_avg_distance() {
        let m = Topology::mesh(8, 8).unwrap();
        let t = Topology::torus(8, 8).unwrap();
        assert!(t.avg_distance() < m.avg_distance());
    }

    #[test]
    fn bisection_mesh_vs_torus() {
        // mesh 4x4 split by id-halves cuts one row of 4 vertical links;
        // torus adds the wrap column links -> 2x.
        let m = Topology::mesh(4, 4).unwrap();
        let t = Topology::torus(4, 4).unwrap();
        assert_eq!(m.bisection_links(), 4);
        assert_eq!(t.bisection_links(), 8);
    }

    #[test]
    fn custom_rejects_bad_edges() {
        assert!(Topology::custom(3, &[(0, 0)]).is_err());
        assert!(Topology::custom(3, &[(0, 5)]).is_err());
        assert!(Topology::custom(3, &[(0, 1), (1, 0)]).is_err());
        let t = Topology::custom(3, &[(0, 1)]).unwrap();
        assert!(!t.is_connected());
    }

    #[test]
    fn reverse_port_round_trips_on_all_topologies() {
        let topos = vec![
            Topology::mesh(4, 3).unwrap(),
            Topology::torus(4, 4).unwrap(),
            Topology::ring(7).unwrap(),
            Topology::star(9).unwrap(),
            Topology::fattree(3).unwrap(),
            Topology::custom(5, &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 0), (1, 3)]).unwrap(),
        ];
        for t in topos {
            for n in 0..t.nodes() {
                for p in 0..t.degree(n) {
                    let (v, lid) = t.neighbors(n)[p];
                    assert_eq!(t.neighbor(n, p), v);
                    let rp = t.reverse_port(n, p);
                    // The reverse port at v leads back to n over the same
                    // physical link.
                    assert_eq!(t.neighbors(v)[rp], (n, lid), "{:?} {n}->{v}", t.kind());
                }
            }
        }
    }

    #[test]
    fn distances_bfs() {
        let t = Topology::mesh(3, 3).unwrap();
        let d = t.distances(0);
        assert_eq!(d[0], 0);
        assert_eq!(d[8], 4);
        assert_eq!(d[4], 2);
    }
}
