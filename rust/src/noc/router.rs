//! Wormhole router building blocks: flits and the contiguous input-buffer
//! arena shared by every router in a [`super::NocSim`].
//!
//! The original implementation kept per-router `Vec<Vec<VecDeque<Flit>>>`
//! buffers — three pointer hops and a heap allocation per queue, which
//! made the cycle loop allocation- and cache-miss-bound. [`FlitQueues`]
//! replaces all of it with one flat arena: every (node, port, vc) input
//! queue is a fixed-capacity ring window inside a single `Vec<Flit>`,
//! addressed by a dense queue id the simulator derives from its per-node
//! prefix offsets. Head/length cursors live in two parallel flat arrays,
//! so stepping a router touches a handful of contiguous cache lines and
//! never allocates.

use super::topology::NodeId;

/// Flit position within its packet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlitKind {
    Head,
    Body,
    /// Tail (a single-flit packet is Head+Tail; we mark it Tail and set
    /// `is_head`).
    Tail,
}

/// One flit of a packet in flight.
#[derive(Debug, Clone, Copy)]
pub struct Flit {
    pub packet: usize,
    pub kind: FlitKind,
    pub is_head: bool,
    pub dst: NodeId,
    pub vc: usize,
}

impl Flit {
    /// Placeholder value for unoccupied arena slots.
    const NULL: Flit =
        Flit { packet: usize::MAX, kind: FlitKind::Body, is_head: false, dst: 0, vc: 0 };
}

/// Contiguous ring-buffer arena of fixed-capacity flit queues.
///
/// Queue `q` owns slots `q*cap .. (q+1)*cap` of the backing buffer and
/// behaves as a bounded FIFO (the credit protocol guarantees a push never
/// exceeds `cap`; this is debug-asserted). All queues share one
/// allocation made at construction time.
#[derive(Debug, Clone)]
pub struct FlitQueues {
    buf: Vec<Flit>,
    head: Vec<u32>,
    len: Vec<u32>,
    cap: usize,
}

impl FlitQueues {
    pub fn new(queues: usize, cap_flits: usize) -> Self {
        assert!(cap_flits > 0, "queues need nonzero capacity");
        FlitQueues {
            buf: vec![Flit::NULL; queues * cap_flits],
            head: vec![0; queues],
            len: vec![0; queues],
            cap: cap_flits,
        }
    }

    /// Per-queue capacity in flits.
    #[inline]
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Number of buffered flits in queue `q`.
    #[inline]
    pub fn len(&self, q: usize) -> usize {
        self.len[q] as usize
    }

    #[inline]
    pub fn is_full(&self, q: usize) -> bool {
        self.len[q] as usize == self.cap
    }

    /// Front flit of queue `q` (copied out; `Flit` is 4 words).
    #[inline]
    pub fn front(&self, q: usize) -> Option<Flit> {
        if self.len[q] == 0 {
            None
        } else {
            Some(self.buf[q * self.cap + self.head[q] as usize])
        }
    }

    #[inline]
    pub fn push_back(&mut self, q: usize, f: Flit) {
        debug_assert!(!self.is_full(q), "queue {q} overflow (credit protocol violated)");
        let slot = q * self.cap + (self.head[q] as usize + self.len[q] as usize) % self.cap;
        self.buf[slot] = f;
        self.len[q] += 1;
    }

    #[inline]
    pub fn pop_front(&mut self, q: usize) -> Flit {
        debug_assert!(self.len[q] > 0, "pop from empty queue {q}");
        let f = self.buf[q * self.cap + self.head[q] as usize];
        self.head[q] = ((self.head[q] as usize + 1) % self.cap) as u32;
        self.len[q] -= 1;
        f
    }

    /// Total buffered flits across all queues (drain checks).
    pub fn total(&self) -> usize {
        self.len.iter().map(|&l| l as usize).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flit(packet: usize) -> Flit {
        Flit { packet, kind: FlitKind::Tail, is_head: true, dst: 0, vc: 0 }
    }

    #[test]
    fn fresh_arena_is_empty() {
        let q = FlitQueues::new(6, 4);
        assert_eq!(q.total(), 0);
        assert_eq!(q.capacity(), 4);
        for i in 0..6 {
            assert_eq!(q.len(i), 0);
            assert!(q.front(i).is_none());
            assert!(!q.is_full(i));
        }
    }

    #[test]
    fn fifo_order_per_queue() {
        let mut q = FlitQueues::new(2, 4);
        for p in 0..4 {
            q.push_back(1, flit(p));
        }
        assert!(q.is_full(1));
        assert_eq!(q.len(0), 0, "queues are independent");
        for p in 0..4 {
            assert_eq!(q.front(1).unwrap().packet, p);
            assert_eq!(q.pop_front(1).packet, p);
        }
        assert_eq!(q.total(), 0);
    }

    #[test]
    fn ring_wraps_within_window() {
        let mut q = FlitQueues::new(3, 2);
        // Push/pop repeatedly so head cycles through the 2-slot window.
        for round in 0..7 {
            q.push_back(2, flit(round));
            q.push_back(2, flit(round + 100));
            assert!(q.is_full(2));
            assert_eq!(q.pop_front(2).packet, round);
            assert_eq!(q.pop_front(2).packet, round + 100);
        }
        // Neighboring queues untouched.
        assert_eq!(q.len(0), 0);
        assert_eq!(q.len(1), 0);
    }

    #[test]
    #[should_panic(expected = "overflow")]
    #[cfg(debug_assertions)]
    fn overflow_panics_in_debug() {
        let mut q = FlitQueues::new(1, 2);
        q.push_back(0, flit(0));
        q.push_back(0, flit(1));
        q.push_back(0, flit(2));
    }
}
