//! Wormhole router building blocks: flits and per-router state.

use super::topology::NodeId;

/// Flit position within its packet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlitKind {
    Head,
    Body,
    /// Tail (a single-flit packet is Head+Tail; we mark it Tail and set
    /// `is_head`).
    Tail,
}

/// One flit of a packet in flight.
#[derive(Debug, Clone, Copy)]
pub struct Flit {
    pub packet: usize,
    pub kind: FlitKind,
    pub is_head: bool,
    pub dst: NodeId,
    pub vc: usize,
}

/// Per-router, per-input-port, per-VC buffer state plus output allocation.
///
/// Wormhole switching: a head flit allocates (output port, vc) and holds
/// it until the tail passes; body flits follow the allocation. Credits
/// count free downstream buffer slots per (port, vc).
#[derive(Debug)]
pub struct RouterState {
    /// in_buf[port][vc] — input queues. Port 0..deg are neighbor links in
    /// `Topology::neighbors` order; port deg is the local injection port.
    pub in_buf: Vec<Vec<std::collections::VecDeque<Flit>>>,
    /// out_owner[port][vc] = Some((in_port, in_vc)) while a packet holds
    /// the output.
    pub out_owner: Vec<Vec<Option<(usize, usize)>>>,
    /// credits[port][vc] = free buffer slots at the downstream input.
    pub credits: Vec<Vec<usize>>,
    /// Round-robin arbitration pointer per output port.
    pub rr: Vec<usize>,
}

impl RouterState {
    pub fn new(ports_in: usize, ports_out: usize, vcs: usize, buf_flits: usize) -> Self {
        RouterState {
            in_buf: (0..ports_in)
                .map(|_| (0..vcs).map(|_| std::collections::VecDeque::new()).collect())
                .collect(),
            out_owner: vec![vec![None; vcs]; ports_out],
            credits: vec![vec![buf_flits; vcs]; ports_out],
            rr: vec![0; ports_out],
        }
    }

    /// Total buffered flits (for drain checks and backpressure stats).
    pub fn occupancy(&self) -> usize {
        self.in_buf.iter().flat_map(|p| p.iter().map(|q| q.len())).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_router_is_empty_with_full_credits() {
        let r = RouterState::new(5, 4, 2, 4);
        assert_eq!(r.occupancy(), 0);
        assert!(r.credits.iter().all(|p| p.iter().all(|&c| c == 4)));
        assert!(r.out_owner.iter().all(|p| p.iter().all(Option::is_none)));
        assert_eq!(r.in_buf.len(), 5);
        assert_eq!(r.out_owner.len(), 4);
    }
}
