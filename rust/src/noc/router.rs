//! Wormhole router building blocks: flits and the contiguous input-buffer
//! arena shared by every router in a [`super::NocSim`].
//!
//! The original implementation kept per-router `Vec<Vec<VecDeque<Flit>>>`
//! buffers — three pointer hops and a heap allocation per queue, which
//! made the cycle loop allocation- and cache-miss-bound. [`FlitQueues`]
//! replaces all of it with one flat arena: every (node, port, vc) input
//! queue is a fixed-capacity ring window inside a single `Vec<Flit>`,
//! addressed by a dense queue id the simulator derives from its per-node
//! prefix offsets. Head/length cursors live in two parallel flat arrays,
//! so stepping a router touches a handful of contiguous cache lines and
//! never allocates.

use super::topology::NodeId;

/// Flit position within its packet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlitKind {
    Head,
    Body,
    /// Tail (a single-flit packet is Head+Tail; we mark it Tail and set
    /// `is_head`).
    Tail,
}

/// One flit of a packet in flight.
#[derive(Debug, Clone, Copy)]
pub struct Flit {
    pub packet: usize,
    pub kind: FlitKind,
    pub is_head: bool,
    pub dst: NodeId,
    pub vc: usize,
}

impl Flit {
    /// Placeholder value for unoccupied arena slots.
    const NULL: Flit =
        Flit { packet: usize::MAX, kind: FlitKind::Body, is_head: false, dst: 0, vc: 0 };
}

/// Contiguous ring-buffer arena of fixed-capacity flit queues.
///
/// Queue `q` owns slots `q*cap .. (q+1)*cap` of the backing buffer and
/// behaves as a bounded FIFO (the credit protocol guarantees a push never
/// exceeds `cap`; this is debug-asserted). All queues share one
/// allocation made at construction time.
#[derive(Debug, Clone)]
pub struct FlitQueues {
    buf: Vec<Flit>,
    head: Vec<u32>,
    len: Vec<u32>,
    cap: usize,
}

impl FlitQueues {
    pub fn new(queues: usize, cap_flits: usize) -> Self {
        assert!(cap_flits > 0, "queues need nonzero capacity");
        FlitQueues {
            buf: vec![Flit::NULL; queues * cap_flits],
            head: vec![0; queues],
            len: vec![0; queues],
            cap: cap_flits,
        }
    }

    /// Per-queue capacity in flits.
    #[inline]
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Number of buffered flits in queue `q`.
    #[inline]
    pub fn len(&self, q: usize) -> usize {
        self.len[q] as usize
    }

    #[inline]
    pub fn is_full(&self, q: usize) -> bool {
        self.len[q] as usize == self.cap
    }

    /// Front flit of queue `q` (copied out; `Flit` is 4 words).
    #[inline]
    pub fn front(&self, q: usize) -> Option<Flit> {
        if self.len[q] == 0 {
            None
        } else {
            Some(self.buf[q * self.cap + self.head[q] as usize])
        }
    }

    #[inline]
    pub fn push_back(&mut self, q: usize, f: Flit) {
        debug_assert!(!self.is_full(q), "queue {q} overflow (credit protocol violated)");
        let slot = q * self.cap + (self.head[q] as usize + self.len[q] as usize) % self.cap;
        self.buf[slot] = f;
        self.len[q] += 1;
    }

    #[inline]
    pub fn pop_front(&mut self, q: usize) -> Flit {
        debug_assert!(self.len[q] > 0, "pop from empty queue {q}");
        let f = self.buf[q * self.cap + self.head[q] as usize];
        self.head[q] = ((self.head[q] as usize + 1) % self.cap) as u32;
        self.len[q] -= 1;
        f
    }

    /// Total buffered flits across all queues (drain checks).
    pub fn total(&self) -> usize {
        self.len.iter().map(|&l| l as usize).sum()
    }

    /// Number of queues in the arena.
    pub fn queues(&self) -> usize {
        self.head.len()
    }

    /// A view over the whole arena (the single-shard fast path — no
    /// per-step allocation).
    pub fn full_view(&mut self) -> FlitQueuesShard<'_> {
        FlitQueuesShard {
            buf: &mut self.buf,
            head: &mut self.head,
            len: &mut self.len,
            cap: self.cap,
            q0: 0,
        }
    }

    /// Split the arena into disjoint mutable shard views at the given
    /// queue-id boundaries (`bounds[0] == 0`, strictly ascending, last ==
    /// [`FlitQueues::queues`]). Shard `i` owns queues
    /// `bounds[i]..bounds[i+1]` and is addressed by *global* queue id,
    /// so simulator code is identical on sharded and whole-arena paths.
    /// The borrows are disjoint slices — safe to hand to parallel
    /// workers. Views are carved lazily by the returned iterator, so the
    /// per-cycle parallel step builds no `Vec` of views (ROADMAP item:
    /// reusable shard-view storage).
    pub fn shard_views<'a>(&'a mut self, bounds: &'a [usize]) -> ShardViews<'a> {
        assert!(bounds.len() >= 2, "need at least one shard");
        assert_eq!(bounds[0], 0, "shard bounds must start at queue 0");
        assert_eq!(*bounds.last().unwrap(), self.head.len(), "bounds must cover the arena");
        for w in bounds.windows(2) {
            assert!(w[0] < w[1], "shard bounds must be strictly increasing");
        }
        ShardViews {
            buf: &mut self.buf,
            head: &mut self.head,
            len: &mut self.len,
            cap: self.cap,
            bounds,
            next: 0,
        }
    }
}

/// Lazy iterator over disjoint [`FlitQueuesShard`] views — see
/// [`FlitQueues::shard_views`]. Successively splits the arena slices, so
/// every yielded view carries the full arena lifetime (views may coexist
/// and cross worker threads).
#[derive(Debug)]
pub struct ShardViews<'a> {
    buf: &'a mut [Flit],
    head: &'a mut [u32],
    len: &'a mut [u32],
    cap: usize,
    bounds: &'a [usize],
    next: usize,
}

impl<'a> Iterator for ShardViews<'a> {
    type Item = FlitQueuesShard<'a>;

    fn next(&mut self) -> Option<FlitQueuesShard<'a>> {
        if self.next + 1 >= self.bounds.len() {
            return None;
        }
        let (q0, q1) = (self.bounds[self.next], self.bounds[self.next + 1]);
        self.next += 1;
        let nq = q1 - q0;
        let (b, rest) = std::mem::take(&mut self.buf).split_at_mut(nq * self.cap);
        self.buf = rest;
        let (h, rest) = std::mem::take(&mut self.head).split_at_mut(nq);
        self.head = rest;
        let (l, rest) = std::mem::take(&mut self.len).split_at_mut(nq);
        self.len = rest;
        Some(FlitQueuesShard { buf: b, head: h, len: l, cap: self.cap, q0 })
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let n = self.bounds.len() - 1 - self.next;
        (n, Some(n))
    }
}

/// Mutable view over a contiguous range of [`FlitQueues`] queues,
/// addressed by global queue id (the view subtracts its own offset).
/// Produced by [`FlitQueues::shard_views`] / [`FlitQueues::full_view`]; the
/// parallel NoC step hands one view per shard to its workers.
#[derive(Debug)]
pub struct FlitQueuesShard<'a> {
    buf: &'a mut [Flit],
    head: &'a mut [u32],
    len: &'a mut [u32],
    cap: usize,
    /// First global queue id owned by this view.
    q0: usize,
}

impl FlitQueuesShard<'_> {
    #[inline]
    fn local(&self, q: usize) -> usize {
        debug_assert!(
            q >= self.q0 && q - self.q0 < self.head.len(),
            "queue {q} outside shard [{}, {})",
            self.q0,
            self.q0 + self.head.len()
        );
        q - self.q0
    }

    /// Number of buffered flits in (global) queue `q`.
    #[inline]
    pub fn len(&self, q: usize) -> usize {
        self.len[self.local(q)] as usize
    }

    /// Front flit of (global) queue `q`.
    #[inline]
    pub fn front(&self, q: usize) -> Option<Flit> {
        let l = self.local(q);
        if self.len[l] == 0 {
            None
        } else {
            Some(self.buf[l * self.cap + self.head[l] as usize])
        }
    }

    #[inline]
    pub fn push_back(&mut self, q: usize, f: Flit) {
        let l = self.local(q);
        debug_assert!(
            (self.len[l] as usize) < self.cap,
            "queue {q} overflow (credit protocol violated)"
        );
        let slot = l * self.cap + (self.head[l] as usize + self.len[l] as usize) % self.cap;
        self.buf[slot] = f;
        self.len[l] += 1;
    }

    #[inline]
    pub fn pop_front(&mut self, q: usize) -> Flit {
        let l = self.local(q);
        debug_assert!(self.len[l] > 0, "pop from empty queue {q}");
        let f = self.buf[l * self.cap + self.head[l] as usize];
        self.head[l] = ((self.head[l] as usize + 1) % self.cap) as u32;
        self.len[l] -= 1;
        f
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flit(packet: usize) -> Flit {
        Flit { packet, kind: FlitKind::Tail, is_head: true, dst: 0, vc: 0 }
    }

    #[test]
    fn fresh_arena_is_empty() {
        let q = FlitQueues::new(6, 4);
        assert_eq!(q.total(), 0);
        assert_eq!(q.capacity(), 4);
        for i in 0..6 {
            assert_eq!(q.len(i), 0);
            assert!(q.front(i).is_none());
            assert!(!q.is_full(i));
        }
    }

    #[test]
    fn fifo_order_per_queue() {
        let mut q = FlitQueues::new(2, 4);
        for p in 0..4 {
            q.push_back(1, flit(p));
        }
        assert!(q.is_full(1));
        assert_eq!(q.len(0), 0, "queues are independent");
        for p in 0..4 {
            assert_eq!(q.front(1).unwrap().packet, p);
            assert_eq!(q.pop_front(1).packet, p);
        }
        assert_eq!(q.total(), 0);
    }

    #[test]
    fn ring_wraps_within_window() {
        let mut q = FlitQueues::new(3, 2);
        // Push/pop repeatedly so head cycles through the 2-slot window.
        for round in 0..7 {
            q.push_back(2, flit(round));
            q.push_back(2, flit(round + 100));
            assert!(q.is_full(2));
            assert_eq!(q.pop_front(2).packet, round);
            assert_eq!(q.pop_front(2).packet, round + 100);
        }
        // Neighboring queues untouched.
        assert_eq!(q.len(0), 0);
        assert_eq!(q.len(1), 0);
    }

    #[test]
    #[should_panic(expected = "overflow")]
    #[cfg(debug_assertions)]
    fn overflow_panics_in_debug() {
        let mut q = FlitQueues::new(1, 2);
        q.push_back(0, flit(0));
        q.push_back(0, flit(1));
        q.push_back(0, flit(2));
    }

    #[test]
    fn shard_views_alias_the_arena_by_global_id() {
        let mut q = FlitQueues::new(6, 3);
        q.push_back(0, flit(10));
        q.push_back(4, flit(40));
        q.push_back(4, flit(41));
        {
            let bounds = [0, 2, 6];
            let mut views = q.shard_views(&bounds);
            assert_eq!(views.size_hint(), (2, Some(2)));
            let s0 = views.next().unwrap();
            let mut s1 = views.next().unwrap();
            assert!(views.next().is_none());
            // Global ids work in each shard's own range (both views
            // coexist — the splits are disjoint).
            assert_eq!(s0.front(0).unwrap().packet, 10);
            assert_eq!(s0.len(1), 0);
            assert_eq!(s1.front(4).unwrap().packet, 40);
            assert_eq!(s1.pop_front(4).packet, 40);
            s1.push_back(5, flit(50));
        }
        // Mutations through the views land in the arena.
        assert_eq!(q.len(4), 1);
        assert_eq!(q.front(4).unwrap().packet, 41);
        assert_eq!(q.front(5).unwrap().packet, 50);
        assert_eq!(q.total(), 3);
    }

    #[test]
    fn full_view_behaves_like_the_arena() {
        let mut q = FlitQueues::new(3, 2);
        {
            let mut v = q.full_view();
            v.push_back(2, flit(7));
            // Ring wrap inside the view.
            v.push_back(0, flit(1));
            v.push_back(0, flit(2));
            assert_eq!(v.pop_front(0).packet, 1);
            v.push_back(0, flit(3));
            assert_eq!(v.len(0), 2);
        }
        assert_eq!(q.pop_front(0).packet, 2);
        assert_eq!(q.pop_front(0).packet, 3);
        assert_eq!(q.front(2).unwrap().packet, 7);
    }

    #[test]
    #[should_panic(expected = "cover the arena")]
    fn shard_bounds_must_cover_all_queues() {
        let mut q = FlitQueues::new(4, 2);
        let _ = q.shard_views(&[0, 3]);
    }
}
