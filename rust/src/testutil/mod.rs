//! Mini property-testing harness.
//!
//! The offline image ships no `proptest`/`quickcheck`, so this module
//! provides the 20% that covers our needs: seeded case generation, a
//! driver that reports the failing seed, and shrink-lite (retry the
//! failing case with "smaller" values drawn from the same seed).
//!
//! ```ignore
//! prop::check(200, |rng| {
//!     let n = rng.below(64) + 1;
//!     // ... build case, assert invariant, return Ok(()) or Err(msg)
//!     Ok(())
//! });
//! ```

/// Load one of the bundled fabric configs (`configs/<name>`) into a built
/// [`crate::fabric::Fabric`] — shared by the golden tests and benches so
/// they exercise the exact same fabrics.
pub fn bundled_fabric(name: &str) -> crate::fabric::Fabric {
    crate::fabric::Fabric::build(
        crate::config::FabricConfig::from_toml(
            &std::fs::read_to_string(crate::repo_root().join("configs").join(name))
                .expect("bundled config readable"),
        )
        .expect("bundled config parses"),
    )
    .expect("bundled fabric builds")
}

/// Concatenate lowered programs into one merged program with dependency
/// indices offset per segment — the "merged schedule" oracle shared by
/// `tests/admission_golden.rs` and `benches/bench_admission.rs`: running
/// `coordinator::cosim` on the concatenation must equal admitting the
/// parts at t=0 in order.
pub fn merge_programs(progs: &[&crate::compiler::FabricProgram]) -> crate::compiler::FabricProgram {
    use crate::compiler::Step;
    let mut steps = Vec::new();
    for p in progs {
        let base = steps.len();
        for s in &p.steps {
            let mut s = s.clone();
            let deps = match &mut s {
                Step::Load { deps, .. } | Step::Transfer { deps, .. } | Step::Exec { deps, .. } => {
                    deps
                }
            };
            for d in deps.iter_mut() {
                *d += base;
            }
            steps.push(s);
        }
    }
    crate::compiler::FabricProgram { steps, producer: Vec::new() }
}

pub mod prop {
    use crate::sim::Rng;

    /// Run `cases` generated checks. Panics with the seed of the first
    /// failing case so it can be replayed deterministically.
    pub fn check<F>(cases: u64, mut f: F)
    where
        F: FnMut(&mut Rng) -> Result<(), String>,
    {
        for seed in 0..cases {
            let mut rng = Rng::new(0xA5C1_0000 ^ seed);
            if let Err(msg) = f(&mut rng) {
                panic!("property failed at seed {seed}: {msg}");
            }
        }
    }

    /// Like [`check`] but with an explicit base seed (replay helper).
    pub fn check_seeded<F>(base: u64, cases: u64, mut f: F)
    where
        F: FnMut(&mut Rng) -> Result<(), String>,
    {
        for seed in 0..cases {
            let mut rng = Rng::new(base ^ seed);
            if let Err(msg) = f(&mut rng) {
                panic!("property failed at seed {seed} (base {base:#x}): {msg}");
            }
        }
    }

    /// Assert helper producing `Result<(), String>` style errors.
    #[macro_export]
    macro_rules! prop_assert {
        ($cond:expr, $($fmt:tt)*) => {
            if !$cond {
                return Err(format!($($fmt)*));
            }
        };
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn passes_when_property_holds() {
            check(50, |rng| {
                let a = rng.below(100);
                let b = rng.below(100);
                if a + b >= a {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            });
        }

        #[test]
        #[should_panic(expected = "property failed at seed")]
        fn reports_failing_seed() {
            check(10, |rng| {
                let v = rng.below(5);
                if v < 4 {
                    Ok(())
                } else {
                    Err(format!("v = {v}"))
                }
            });
        }

        #[test]
        fn macro_returns_err() {
            fn inner(x: u32) -> Result<(), String> {
                prop_assert!(x < 10, "x too big: {x}");
                Ok(())
            }
            assert!(inner(5).is_ok());
            assert!(inner(50).is_err());
        }
    }
}
