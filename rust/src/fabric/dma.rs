//! DMA engine model for CU templates B and C.

use crate::metrics::{Category, Metrics};
use crate::sim::Cycle;

/// A simple burst DMA: fixed programming cost + streaming at a set width.
#[derive(Debug, Clone, Copy)]
pub struct Dma {
    /// Bytes moved per fabric cycle once streaming.
    pub bytes_per_cycle: f64,
    /// Descriptor programming + arbitration, cycles per transfer.
    pub setup_cycles: Cycle,
    /// Local interconnect energy, pJ/byte.
    pub e_pj_byte: f64,
}

impl Default for Dma {
    fn default() -> Self {
        Dma { bytes_per_cycle: 64.0, setup_cycles: 16, e_pj_byte: 0.2 }
    }
}

impl Dma {
    /// Cost of one transfer of `bytes`. Time-invariant primitive — a
    /// TCDM-contention-aware staging model would wrap the tile execute
    /// path in [`super::cost::CostModel`] rather than hook here.
    pub fn transfer(&self, bytes: u64) -> Metrics {
        let mut m = Metrics::new();
        if bytes == 0 {
            return m;
        }
        m.cycles = self.setup_cycles + (bytes as f64 / self.bytes_per_cycle).ceil() as Cycle;
        m.bytes_moved = bytes;
        m.add_energy(Category::Sram, bytes as f64 * self.e_pj_byte);
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_transfer_is_free() {
        assert_eq!(Dma::default().transfer(0).cycles, 0);
    }

    #[test]
    fn setup_dominates_small_streaming_dominates_large() {
        let d = Dma::default();
        let small = d.transfer(8);
        assert_eq!(small.cycles, 16 + 1);
        let large = d.transfer(1 << 20);
        assert!(large.cycles > 16_000);
        assert!((large.cycles - d.setup_cycles) as f64 >= (1 << 20) as f64 / d.bytes_per_cycle);
    }
}
