//! High-Bandwidth Memory model (the fabric's external memory).
//!
//! Analytic channel model used on the co-simulation fast path; the
//! timing-accurate HBM2 bank model lives in `dram::DramSim` and is used
//! by experiment E3 to validate these constants.

use crate::metrics::{Category, Metrics};

/// Multi-channel HBM stack.
#[derive(Debug, Clone, Copy)]
pub struct Hbm {
    pub channels: usize,
    /// Per-channel bandwidth, GB/s.
    pub gbs_per_channel: f64,
    /// Access energy, pJ/byte (HBM2: ~3.9).
    pub e_pj_byte: f64,
    /// Fixed access latency, fabric cycles.
    pub latency_cycles: u64,
}

impl Hbm {
    pub fn new(channels: usize, gbs_per_channel: f64, e_pj_byte: f64) -> Self {
        Hbm { channels, gbs_per_channel, e_pj_byte, latency_cycles: 100 }
    }

    /// Aggregate bandwidth, GB/s.
    pub fn total_gbs(&self) -> f64 {
        self.channels as f64 * self.gbs_per_channel
    }

    /// Cost of reading/writing `bytes` (channel-striped), at a 1 GHz
    /// fabric reference clock. Time-invariant primitive: queue-depth /
    /// congestion awareness lives in [`super::cost::CostModel`]
    /// (e.g. [`super::VaryingCost`] stretches the feed latency by the
    /// previous epoch's resident-transfer integral).
    pub fn access(&self, bytes: u64) -> Metrics {
        let mut m = Metrics::new();
        if bytes == 0 {
            return m;
        }
        let bytes_per_cycle = self.total_gbs(); // GB/s at 1 GHz = B/cycle
        m.cycles = self.latency_cycles + (bytes as f64 / bytes_per_cycle).ceil() as u64;
        m.bytes_moved = bytes;
        m.add_energy(Category::Dram, bytes as f64 * self.e_pj_byte);
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bandwidth_scales_with_channels() {
        let one = Hbm::new(1, 64.0, 3.9);
        let four = Hbm::new(4, 64.0, 3.9);
        let big = 1 << 24;
        assert!(one.access(big).cycles > 3 * four.access(big).cycles);
        assert_eq!(four.total_gbs(), 256.0);
    }

    #[test]
    fn latency_floor_for_small_access() {
        let h = Hbm::new(4, 64.0, 3.9);
        assert_eq!(h.access(64).cycles, 100 + 1);
    }

    #[test]
    fn energy_linear_in_bytes() {
        let h = Hbm::new(2, 64.0, 3.9);
        let a = h.access(1000).total_energy_pj();
        let b = h.access(2000).total_energy_pj();
        assert!((b / a - 2.0).abs() < 1e-9);
    }
}
