//! PULP-style multi-core cluster model (CU template C's shell).
//!
//! `cores` RISC-V cores share a banked TCDM through a logarithmic
//! interconnect. Elementwise/pre/post work parallelizes across cores;
//! TCDM banking conflicts derate throughput as contention grows (the
//! classic PULP p(conflict) curve, first-order approximation).

use crate::metrics::{Category, Metrics};

/// Cluster shell parameters.
#[derive(Debug, Clone, Copy)]
pub struct PulpCluster {
    pub cores: usize,
    pub tcdm_banks: usize,
    /// Per-core ops per cycle on elementwise work.
    pub ops_per_core_cycle: f64,
    /// Core energy per cycle, pJ.
    pub e_core_cycle_pj: f64,
}

impl PulpCluster {
    pub fn new(cores: usize) -> Self {
        PulpCluster {
            cores: cores.max(1),
            tcdm_banks: (2 * cores).max(2),
            ops_per_core_cycle: 1.0,
            e_core_cycle_pj: 8.0,
        }
    }

    /// Expected slowdown from TCDM banking conflicts with `cores`
    /// requesters over `banks` banks (random addresses):
    /// E[serialization] ≈ 1 / (1 - collisions) with
    /// p(any collision) from the birthday approximation.
    pub fn contention_factor(&self) -> f64 {
        let n = self.cores as f64;
        let b = self.tcdm_banks as f64;
        // Expected max-load serialization, first order: 1 + (n-1)/(2b).
        1.0 + (n - 1.0) / (2.0 * b)
    }

    /// Cost of `elems` elementwise operations spread across the cores.
    pub fn elementwise(&self, elems: usize) -> Metrics {
        let mut m = Metrics::new();
        m.ops = elems as u64;
        let ideal = elems as f64 / (self.cores as f64 * self.ops_per_core_cycle);
        m.cycles = (ideal * self.contention_factor()).ceil() as u64;
        m.cycles = m.cycles.max(1);
        m.add_energy(
            Category::Compute,
            m.cycles as f64 * self.cores as f64 * self.e_core_cycle_pj,
        );
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn more_cores_faster_elementwise() {
        let c2 = PulpCluster::new(2);
        let c8 = PulpCluster::new(8);
        let e = 100_000;
        assert!(c8.elementwise(e).cycles < c2.elementwise(e).cycles / 2);
    }

    #[test]
    fn contention_grows_with_cores_per_bank() {
        let balanced = PulpCluster::new(8); // 16 banks
        let mut starved = PulpCluster::new(8);
        starved.tcdm_banks = 4;
        assert!(starved.contention_factor() > balanced.contention_factor());
        assert!(balanced.contention_factor() >= 1.0);
    }

    #[test]
    fn energy_charged_for_all_cores_while_busy() {
        let c = PulpCluster::new(4);
        let m = c.elementwise(4000);
        let expect = m.cycles as f64 * 4.0 * c.e_core_cycle_pj;
        assert!((m.total_energy_pj() - expect).abs() < 1e-9);
    }
}
