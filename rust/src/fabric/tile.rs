//! Compute-Unit tile: an accelerator behind one of the three integration
//! templates of paper Fig. 1. The template decides control overhead,
//! operand staging, and how many bytes must cross the NoC per invocation.

use anyhow::bail;

use crate::accel::{Accelerator, Compute, Precision};
use crate::metrics::{Area, Category, Metrics};
use crate::noc::NodeId;
use crate::Result;

use super::{Dma, PulpCluster};

/// Integration template (paper Fig. 1 A/B/C).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Template {
    /// Stand-alone accelerator with NoC interface.
    A,
    /// Light-weight wrapper: RISC-V controller + TCDM + DMA.
    B,
    /// PULP-style multi-core cluster around the accelerator.
    C,
}

impl Template {
    pub fn from_char(c: char) -> Result<Self> {
        Ok(match c {
            'A' => Template::A,
            'B' => Template::B,
            'C' => Template::C,
            other => bail!("unknown CU template {other:?}"),
        })
    }

    /// Control overhead per op invocation, fabric cycles (host descriptor
    /// for A; controller-core launch for B; cluster barrier + launch for C).
    fn ctrl_cycles(self) -> u64 {
        match self {
            Template::A => 100,
            Template::B => 300,
            Template::C => 500,
        }
    }

    fn ctrl_energy_pj(self) -> f64 {
        self.ctrl_cycles() as f64 * 5.0
    }
}

/// Result of running one op on a tile: time/energy on the tile itself
/// plus the bytes the caller must move over the NoC.
#[derive(Debug, Clone)]
pub struct TileCost {
    /// Tile-local metrics in *fabric* cycles.
    pub metrics: Metrics,
    /// Operand bytes that cross the NoC for this invocation.
    pub noc_bytes: u64,
}

/// The config-level accelerator kind a tile was built from. Distinct
/// from `accel.name()` (the *device model* name): `"crossbar"` and
/// `"pim_dram"` both instantiate [`crate::accel::CrossbarNvm`], but a
/// PIM tile sits in the DRAM die and prices differently
/// ([`super::KindCost`]). Fault plans key on device names, not on this
/// enum, so adding kinds never perturbs existing fault timelines.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TileKind {
    Npu,
    Crossbar,
    Photonic,
    Neuromorphic,
    PimDram,
    Cpu,
}

impl TileKind {
    /// Parse a `[[cu]] kind` config string (the `CU_KINDS` vocabulary).
    pub fn from_config_str(s: &str) -> Option<Self> {
        Some(match s {
            "npu" => TileKind::Npu,
            "crossbar" => TileKind::Crossbar,
            "photonic" => TileKind::Photonic,
            "neuromorphic" => TileKind::Neuromorphic,
            "pim_dram" => TileKind::PimDram,
            "cpu" => TileKind::Cpu,
            _ => return None,
        })
    }

    pub fn as_str(self) -> &'static str {
        match self {
            TileKind::Npu => "npu",
            TileKind::Crossbar => "crossbar",
            TileKind::Photonic => "photonic",
            TileKind::Neuromorphic => "neuromorphic",
            TileKind::PimDram => "pim_dram",
            TileKind::Cpu => "cpu",
        }
    }
}

/// One placed Compute Unit.
pub struct Tile {
    pub id: usize,
    pub node: NodeId,
    pub accel: Box<dyn Accelerator>,
    /// Config kind the tile was instantiated from (pricing dimension).
    pub kind: TileKind,
    pub template: Template,
    pub tcdm_bytes: usize,
    pub cluster: Option<PulpCluster>,
    pub dma: Dma,
    /// Fabric clock the tile is integrated at, GHz.
    pub fabric_ghz: f64,
}

impl Tile {
    pub fn new(
        id: usize,
        node: NodeId,
        accel: Box<dyn Accelerator>,
        kind: TileKind,
        template: Template,
        tcdm_bytes: usize,
        cluster_cores: usize,
    ) -> Self {
        let cluster = match template {
            Template::C => Some(PulpCluster::new(cluster_cores)),
            _ => None,
        };
        Tile {
            id,
            node,
            accel,
            kind,
            template,
            tcdm_bytes,
            cluster,
            dma: Dma::default(),
            fabric_ghz: 1.0,
        }
    }

    /// Does this tile's accelerator run precision `p`?
    pub fn supports(&self, p: Precision) -> bool {
        self.accel.supports(p) || self.cluster.is_some()
    }

    /// Convert device cycles to fabric cycles.
    #[allow(dead_code)] // used by unit tests; the exec path inlines it
    fn to_fabric_cycles(&self, dev_cycles: u64) -> u64 {
        ((dev_cycles as f64) * self.fabric_ghz / self.accel.freq_ghz()).ceil() as u64
    }

    /// Execute one compute op on this tile — the **time-invariant
    /// pricing primitive** (the start-time-aware seam moved up into
    /// [`super::cost::CostModel::execute`], where a DVFS/thermal model
    /// like [`super::VaryingCost`] stretches this base cost by the
    /// tile's windowed busy integral).
    ///
    /// * Template A: every operand (weights included) streams over the
    ///   NoC, no overlap: latency = ctrl + transfer-in-accel-out serial.
    ///   The NoC share is returned to the caller; the serial dependency
    ///   is approximated by the caller adding transport latency.
    /// * Template B: weights resident in TCDM when they fit (amortized to
    ///   zero steady-state traffic), activations DMA-staged and
    ///   double-buffered: latency = ctrl + max(accel, dma).
    /// * Template C: as B; elementwise ops run on the cluster cores
    ///   instead of the accelerator.
    pub fn execute(&self, c: &Compute, p: Precision) -> Result<TileCost> {
        let run_on_cluster = matches!(c, Compute::Elementwise { .. }) && self.cluster.is_some();
        if !run_on_cluster && !self.accel.supports(p) {
            bail!(
                "tile {} ({}) does not support {:?}",
                self.id,
                self.accel.name(),
                p
            );
        }
        let mut out = Metrics::new();
        out.add_energy(Category::Host, self.template.ctrl_energy_pj());

        let (core, dev_ghz) = if run_on_cluster {
            let cl = self.cluster.as_ref().unwrap();
            let elems = match c {
                Compute::Elementwise { elems } => *elems,
                _ => unreachable!(),
            };
            (cl.elementwise(elems), self.fabric_ghz)
        } else {
            (self.accel.cost(c, p), self.accel.freq_ghz())
        };
        let accel_fabric_cycles =
            ((core.cycles as f64) * self.fabric_ghz / dev_ghz).ceil() as u64;

        let io = c.io_bytes(p);
        let weights = c.weight_bytes(p);
        let (noc_bytes, tile_cycles) = match self.template {
            Template::A => {
                // Everything streams over NoC; accel starts after inputs
                // land (caller adds transport); no local staging.
                (io + weights, accel_fabric_cycles)
            }
            Template::B | Template::C => {
                let weights_resident = (weights as usize) <= self.tcdm_bytes / 2;
                let stream = if weights_resident { io } else { io + weights };
                let dma = self.dma.transfer(stream);
                out.absorb_parallel(&dma.with_cycles(0));
                // Double buffering: DMA overlaps compute.
                (stream, accel_fabric_cycles.max(dma.cycles))
            }
        };
        out.cycles = self.template.ctrl_cycles() + tile_cycles;
        for (cat, pj) in core.breakdown() {
            out.add_energy(cat, pj);
        }
        out.ops = core.ops;
        out.bytes_moved += noc_bytes;
        Ok(TileCost { metrics: out, noc_bytes })
    }

    pub fn area(&self) -> Area {
        let shell = match self.template {
            Template::A => 0.1,
            Template::B => 0.4 + self.tcdm_bytes as f64 / 1e6 * 0.5, // SRAM macro
            Template::C => {
                0.4 + self.tcdm_bytes as f64 / 1e6 * 0.5
                    + self.cluster.as_ref().map_or(0.0, |c| c.cores as f64 * 0.15)
            }
        };
        Area::new(self.accel.area().mm2 + shell)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::DigitalNpu;

    fn tile(template: Template) -> Tile {
        Tile::new(
            0,
            1,
            Box::new(DigitalNpu::default()),
            TileKind::Npu,
            template,
            256 * 1024,
            8,
        )
    }

    #[test]
    fn kind_round_trips_the_config_vocabulary() {
        for s in ["npu", "crossbar", "photonic", "neuromorphic", "pim_dram", "cpu"] {
            let k = TileKind::from_config_str(s).unwrap();
            assert_eq!(k.as_str(), s);
        }
        assert!(TileKind::from_config_str("tpu").is_none());
    }

    fn mm() -> Compute {
        Compute::MatMul { m: 64, k: 256, n: 128 }
    }

    #[test]
    fn template_a_streams_weights_every_call() {
        let a = tile(Template::A).execute(&mm(), Precision::Int8).unwrap();
        let b = tile(Template::B).execute(&mm(), Precision::Int8).unwrap();
        assert!(a.noc_bytes > b.noc_bytes, "{} vs {}", a.noc_bytes, b.noc_bytes);
        assert_eq!(
            a.noc_bytes - b.noc_bytes,
            mm().weight_bytes(Precision::Int8)
        );
    }

    #[test]
    fn template_b_overlaps_dma_with_compute() {
        // Per-tile latency (excluding NoC) should be ctrl + max(parts),
        // strictly less than ctrl_a + sum(parts) for a feed-heavy op.
        let b = tile(Template::B).execute(&mm(), Precision::Int8).unwrap();
        let tb = tile(Template::B);
        let accel_only = tb.to_fabric_cycles(tb.accel.cost(&mm(), Precision::Int8).cycles);
        let dma_only = tb.dma.transfer(mm().io_bytes(Precision::Int8)).cycles;
        assert_eq!(
            b.metrics.cycles,
            Template::B.ctrl_cycles() + accel_only.max(dma_only)
        );
    }

    #[test]
    fn big_weights_overflow_tcdm_and_stream() {
        let huge = Compute::MatMul { m: 8, k: 1024, n: 512 }; // 512 KiB int8
        let t = tile(Template::B);
        let cost = t.execute(&huge, Precision::Int8).unwrap();
        assert!(cost.noc_bytes >= huge.weight_bytes(Precision::Int8));
    }

    #[test]
    fn cluster_absorbs_elementwise() {
        let c = tile(Template::C);
        let cost = c.execute(&Compute::Elementwise { elems: 100_000 }, Precision::F32).unwrap();
        // 8 cores at ~1 op/cycle: ~12.5k cycles + ctrl, far below the
        // NPU vector unit? NPU does 128/cycle — the point here is that
        // the cluster path *works* and is charged to cluster energy.
        assert!(cost.metrics.cycles > Template::C.ctrl_cycles());
        assert!(cost.metrics.total_energy_pj() > 0.0);
    }

    #[test]
    fn unsupported_precision_fails() {
        let t = tile(Template::A);
        assert!(t.execute(&mm(), Precision::Analog).is_err());
    }

    #[test]
    fn area_ordering_a_b_c() {
        let (a, b, c) = (tile(Template::A), tile(Template::B), tile(Template::C));
        assert!(a.area().mm2 < b.area().mm2);
        assert!(b.area().mm2 < c.area().mm2);
    }

    #[test]
    fn e1_shape_b_beats_a_on_latency_for_reused_weights() {
        // Template A pays weight transfer every call; B amortizes via
        // TCDM residency — with transport added, B wins. Here we check
        // the tile-local part of that claim: B's noc_bytes are smaller
        // and its latency not worse beyond the ctrl delta.
        let a = tile(Template::A).execute(&mm(), Precision::Int8).unwrap();
        let b = tile(Template::B).execute(&mm(), Precision::Int8).unwrap();
        assert!(b.noc_bytes < a.noc_bytes);
        assert!(b.metrics.cycles <= a.metrics.cycles + 300);
    }
}
