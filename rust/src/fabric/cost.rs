//! The cost-model layer: every start-time-aware resource query of the
//! co-simulation stack routes through one [`CostModel`] — the swappable
//! analytic pricing seam between the exact event engines
//! (`coordinator::exec` / `coordinator::admit`) and the fabric's
//! resource models, in the Timeloop/gem5 spirit of separating *what a
//! step costs* from *when the engine replays it*.
//!
//! PR 2 grew `transport_at`/`feed_at`/`execute_at`/… hooks on the fabric
//! types and PR 4 made their time-invariance load-bearing for incremental
//! re-simulation. This module **replaces** those hooks (they are gone —
//! a contract migration, not an addition): the engines now hold a model
//! handle and the model declares its own time dependence, so
//! time-varying pricing (congestion, DVFS/thermal throttling) plugs in
//! without touching an engine, and the admission session knows which
//! invalidation rule the model requires.
//!
//! # The contract
//!
//! A cost model must be a **pure, deterministic function** of
//!
//! * the fabric description,
//! * the step parameters (`src`/`dst`/`bytes`/`compute`/`precision`),
//! * the start cycle, and
//! * occupancy reads of **strictly earlier epochs** (below).
//!
//! No interior mutability, no iteration-order-dependent reads, no clock
//! or RNG. Identical inputs must produce bit-identical [`Metrics`].
//!
//! # Time dependence and the epoch quantization
//!
//! [`CostModel::time_dependence`] is self-declared:
//!
//! * [`TimeDependence::Invariant`] — the price ignores `start` and the
//!   occupancy entirely. The engines then skip occupancy tracking and the
//!   admission session keeps the (cheaper) structural-only invalidation
//!   closure of PR 4; every report stays bit-identical to the
//!   pre-cost-layer engines (`tests/admission_golden.rs` pins this).
//! * [`TimeDependence::VaryingAfter(w)`] — the price at start `s` may
//!   read occupancy, but only aggregated over epochs **strictly before**
//!   `epoch(s) = s / w` (epoch length `w` cycles). The admission session
//!   then widens invalidation to the *time horizon* (every scheduled step
//!   with start ≥ the perturbation time) and runs a fixed-point
//!   re-pricing loop (see `coordinator::admit`).
//!
//! The strictly-earlier-epoch rule is what makes the whole design exact:
//! it stratifies the schedule by epoch, so the self-consistent schedule
//! (every step priced against the occupancy of the final schedule) is
//! **unique** — occupancy of epochs `< k` is fully determined by steps
//! starting before epoch `k`, so two self-consistent schedules agreeing
//! before their earliest divergence must also agree at it. Uniqueness is
//! why an incremental session, a from-scratch session, the event engine
//! and the iterated list scheduler all converge to bit-identical reports
//! (pinned by `tests/costmodel_golden.rs`). A model that reads its own
//! epoch (or a future one) voids that guarantee; the session's settle
//! loop would still terminate or error, but the differential goldens
//! would catch the divergence.
//!
//! The same rule is also the *parallelism* lever: because a price at
//! start `s` reads only epochs `< s / w`, every fire in one calendar
//! batch whose starts share an epoch can be priced against a frozen
//! batch-start occupancy snapshot, on any thread, and still produce the
//! sequential bits. The shard-parallel admission drain
//! (`coordinator::admit`, module docs) exploits exactly this — models
//! are `Send + Sync` and pricing is a pure read, so a `&dyn CostModel`
//! plus an `&Occupancy` snapshot cross worker threads with no locking.
//! Nothing in this module needed to change for that: purity *is* the
//! shard-safety property.
//!
//! # Shipped models
//!
//! * [`InvariantCost`] — delegates to the analytic fabric models
//!   bit-for-bit; the default (`[fabric.cost] model = "invariant"`).
//! * [`VaryingCost`] — the time-varying model family, with two orthogonal
//!   mechanisms that can be enabled independently or together:
//!   * **congestion** (link/HBM): transfer-class latency scales with the
//!     average number of concurrently-resident transfer steps during the
//!     previous epoch (`factor = min(cap, 1 + alpha · resident)`);
//!   * **DVFS/thermal** (tiles): a tile whose busy fraction over a
//!     trailing window of epochs crosses the warm/hot thresholds is
//!     frequency-throttled (`cycles / scale`, discrete levels — discrete
//!     so the fixed point settles in few passes). Energy is left
//!     unscaled: congestion and throttling stretch time, they do not
//!     move more bits or switch more gates in this model family.
//! * [`KindCost`] — kind-aware accelerator pricing (`model = "kind"`):
//!   the first model that consults [`TileKind`], making the paper's
//!   heterogeneous post-CMOS device classes first-class in the pricing
//!   layer instead of generic resources.
//!
//! # Kind-aware pricing rules (`KindCost`)
//!
//! Every kind-specific modifier obeys the same contract clauses as the
//! generic models — the kinds change *what* is priced, never *how* the
//! occupancy is read:
//!
//! * **Photonic warm-up** — a photonic tile is *warm* when its busy
//!   fraction over a trailing window of fully elapsed epochs (the same
//!   aggregates the DVFS throttle reads) is at/above
//!   [`KindKnobs::photonic_warm_frac`]; a cold start pays
//!   [`KindKnobs::photonic_warmup_cycles`] of laser ramp-up /
//!   ring-resonator thermal tuning plus
//!   [`KindKnobs::photonic_tuning_pj`] of [`Category::Laser`] energy.
//!   Epoch 0 (and a disabled occupancy) is always cold — warm state is
//!   history, and there is none yet.
//! * **Crossbar wear** — an NVM crossbar's program/erase wear counter is
//!   the tile's *cumulative* busy integral over all strictly earlier
//!   epochs, so the wear factor `min(cap, 1 + alpha · busy/epoch)` is
//!   **monotone nondecreasing in start** within any fixed schedule:
//!   wear only ever accumulates. It stretches both latency and the
//!   per-access ADC/DAC overhead energy ([`Category::Adc`], priced per
//!   operand byte crossing the analog boundary).
//! * **Neuromorphic spike rate** — event-driven energy scales with the
//!   step's op/byte mix: arithmetic intensity at/below
//!   [`KindKnobs::neuro_sparse_intensity`] prices compute + leakage
//!   energy at the sparse scale (idle neurons gate off), above it at
//!   the dense scale (spike storms). Pure function of the step — no
//!   occupancy read, no time dependence.
//! * **PIM offload vs. DRAM contention** — a `pim_dram` tile's HBM feed
//!   burns less DRAM energy ([`KindKnobs::pim_offload_scale`]: operands
//!   are already in the DRAM die), but its executes contend with
//!   transfer traffic for banks: the previous epoch's resident-transfer
//!   integral stretches exec latency exactly like the congestion factor.
//!
//! All of warm-up, wear and contention read **strictly earlier epochs
//! only**, so the unique-fixed-point argument above applies unchanged
//! and `tests/kindcost_golden.rs` pins incremental ≡ from-scratch ≡
//! cross-engine bit-identity on the mixed-kind config.
//!
//! Every kind modifier is a time **tax or par** — photonic warm-up
//! adds, crossbar wear and PIM contention stretch by factors ≥ 1,
//! neuromorphic and PIM offload touch energy only. With fixed step →
//! tile assignments, finish times are monotone in step durations, so
//! the invariant estimate of any program is a *cycles floor* for its
//! kind-aware price (also pinned in `tests/kindcost_golden.rs`).
//!
//! # The mapper-feedback seam
//!
//! `compiler::mapper::map_graph` routes its placement estimates through
//! [`Fabric::cost_model`] (the `map_graph_with` seam) at `start = 0`
//! with a disabled occupancy. For every kind-blind model this is
//! bit-identical to the old direct-primitive estimates (congestion and
//! DVFS factors are exactly 1.0 at epoch 0), so existing placements are
//! preserved; under `KindCost` the mapper sees cold-start photonic
//! penalties and crossbar interface overheads, and placement moves on
//! mixed fabrics (pinned in `tests/kindcost_golden.rs`).

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::bail;

use crate::accel::{Compute, Precision};
use crate::compiler::Step;
use crate::config::CostConfig;
use crate::metrics::{Category, Metrics};
use crate::noc::NodeId;
use crate::sim::Cycle;
use crate::Result;

use super::{Fabric, TileCost, TileKind};

/// Self-declared time dependence of a [`CostModel`] (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TimeDependence {
    /// Prices ignore `start` and occupancy; structural invalidation
    /// suffices and reports match the pre-cost-layer engines bit-for-bit.
    Invariant,
    /// Prices may vary with `start`, reading occupancy aggregated over
    /// epochs of the given length — **strictly earlier** epochs only.
    /// Requires the admission session's horizon invalidation + settle
    /// loop.
    VaryingAfter(Cycle),
}

impl TimeDependence {
    /// Epoch length when time-varying, `None` when invariant.
    pub fn epoch(self) -> Option<Cycle> {
        match self {
            TimeDependence::Invariant => None,
            TimeDependence::VaryingAfter(w) => Some(w),
        }
    }
}

/// Live resource-occupancy aggregates an engine feeds its time-varying
/// cost model: per-epoch integrals of transfer residency (Load/Transfer
/// steps in flight on the HBM port / NoC links) and per-tile busy
/// cycles. All counters are integers, so registering and retracting a
/// step's span is *exact* — the admission engine's invalidation can
/// subtract a contribution and land on the same bits as never having
/// added it (a float accumulator could not).
#[derive(Debug, Clone)]
pub struct Occupancy {
    /// Epoch length in cycles; 0 = tracking disabled (invariant model).
    epoch: Cycle,
    /// epoch -> resident transfer cycles (sum of per-step overlap).
    transfer: HashMap<u64, u64>,
    /// (tile, epoch) -> busy cycles.
    tile_busy: HashMap<(u32, u64), u64>,
}

impl Occupancy {
    /// Tracking occupancy at the given epoch length.
    pub fn new(epoch: Cycle) -> Self {
        assert!(epoch > 0, "occupancy epoch must be positive");
        Occupancy { epoch, transfer: HashMap::new(), tile_busy: HashMap::new() }
    }

    /// A disabled instance for invariant models: all adds are no-ops and
    /// all reads return 0.
    pub fn disabled() -> Self {
        Occupancy { epoch: 0, transfer: HashMap::new(), tile_busy: HashMap::new() }
    }

    pub fn is_tracking(&self) -> bool {
        self.epoch > 0
    }

    /// Visit `(epoch, overlap cycles)` for every epoch the span
    /// `[start, finish)` intersects.
    fn for_epochs(epoch: Cycle, start: Cycle, finish: Cycle, mut f: impl FnMut(u64, u64)) {
        if epoch == 0 || finish <= start {
            return;
        }
        let mut e = start / epoch;
        let last = (finish - 1) / epoch;
        while e <= last {
            let lo = start.max(e * epoch);
            let hi = finish.min((e + 1) * epoch);
            f(e, hi - lo);
            e += 1;
        }
    }

    /// Register a transfer-class step (HBM load or NoC transfer) resident
    /// over `[start, finish)`.
    pub fn add_transfer(&mut self, start: Cycle, finish: Cycle) {
        let transfer = &mut self.transfer;
        Self::for_epochs(self.epoch, start, finish, |e, c| {
            *transfer.entry(e).or_insert(0) += c;
        });
    }

    /// Retract a previously registered transfer span — exact inverse.
    pub fn remove_transfer(&mut self, start: Cycle, finish: Cycle) {
        let transfer = &mut self.transfer;
        Self::for_epochs(self.epoch, start, finish, |e, c| {
            let v = transfer.get_mut(&e).expect("retracting unknown transfer span");
            *v -= c;
            if *v == 0 {
                transfer.remove(&e);
            }
        });
    }

    /// Register tile busy time over `[start, finish)`.
    pub fn add_tile_busy(&mut self, tile: usize, start: Cycle, finish: Cycle) {
        let tile_busy = &mut self.tile_busy;
        Self::for_epochs(self.epoch, start, finish, |e, c| {
            *tile_busy.entry((tile as u32, e)).or_insert(0) += c;
        });
    }

    /// Retract a previously registered tile-busy span — exact inverse.
    pub fn remove_tile_busy(&mut self, tile: usize, start: Cycle, finish: Cycle) {
        let tile_busy = &mut self.tile_busy;
        Self::for_epochs(self.epoch, start, finish, |e, c| {
            let key = (tile as u32, e);
            let v = tile_busy.get_mut(&key).expect("retracting unknown busy span");
            *v -= c;
            if *v == 0 {
                tile_busy.remove(&key);
            }
        });
    }

    /// Register the occupancy span of one program step: `Exec` steps
    /// charge their tile's busy integral, `Load`/`Transfer` steps the
    /// shared resident-transfer integral. Keeping the classification in
    /// one place keeps [`Occupancy::remove_step`] its exact inverse.
    pub fn add_step(&mut self, step: &Step, start: Cycle, finish: Cycle) {
        match step {
            Step::Exec { tile, .. } => self.add_tile_busy(*tile, start, finish),
            Step::Load { .. } | Step::Transfer { .. } => self.add_transfer(start, finish),
        }
    }

    /// Exact inverse of [`Occupancy::add_step`].
    pub fn remove_step(&mut self, step: &Step, start: Cycle, finish: Cycle) {
        match step {
            Step::Exec { tile, .. } => self.remove_tile_busy(*tile, start, finish),
            Step::Load { .. } | Step::Transfer { .. } => self.remove_transfer(start, finish),
        }
    }

    /// Resident transfer cycles integrated over epoch `e`.
    pub fn transfer_cycles(&self, e: u64) -> u64 {
        self.transfer.get(&e).copied().unwrap_or(0)
    }

    /// Busy cycles of `tile` within epoch `e`.
    pub fn tile_busy_cycles(&self, tile: usize, e: u64) -> u64 {
        self.tile_busy.get(&(tile as u32, e)).copied().unwrap_or(0)
    }
}

/// The cost-model layer every resource query routes through (module docs
/// carry the purity + strictly-earlier-epoch contract).
pub trait CostModel: Send + Sync {
    /// Self-declared time dependence; drives occupancy tracking and the
    /// admission session's invalidation rule.
    fn time_dependence(&self) -> TimeDependence;

    /// Short stable identifier (for logs / config round-trips).
    fn name(&self) -> &'static str;

    /// Price a NoC transport of `bytes` from node `src` to `dst`
    /// launching at `start`.
    fn transport(
        &self,
        fabric: &Fabric,
        src: NodeId,
        dst: NodeId,
        bytes: u64,
        start: Cycle,
        occ: &Occupancy,
    ) -> Metrics;

    /// Price an HBM→tile feed (channel access + NoC leg) launching at
    /// `start`.
    fn feed(&self, fabric: &Fabric, tile: usize, bytes: u64, start: Cycle, occ: &Occupancy)
        -> Metrics;

    /// Price one compute invocation on `tile` launching at `start`.
    fn execute(
        &self,
        fabric: &Fabric,
        tile: usize,
        c: &Compute,
        p: Precision,
        start: Cycle,
        occ: &Occupancy,
    ) -> Result<TileCost>;
}

/// Time-invariant model: delegates to the analytic fabric primitives
/// bit-for-bit. This is the pre-refactor pricing path — the differential
/// goldens pin every engine under this model to the PR 4 reports.
#[derive(Debug, Clone, Copy, Default)]
pub struct InvariantCost;

impl CostModel for InvariantCost {
    fn time_dependence(&self) -> TimeDependence {
        TimeDependence::Invariant
    }

    fn name(&self) -> &'static str {
        "invariant"
    }

    fn transport(
        &self,
        fabric: &Fabric,
        src: NodeId,
        dst: NodeId,
        bytes: u64,
        _start: Cycle,
        _occ: &Occupancy,
    ) -> Metrics {
        fabric.transport(src, dst, bytes)
    }

    fn feed(
        &self,
        fabric: &Fabric,
        tile: usize,
        bytes: u64,
        _start: Cycle,
        _occ: &Occupancy,
    ) -> Metrics {
        fabric.feed(tile, bytes)
    }

    fn execute(
        &self,
        fabric: &Fabric,
        tile: usize,
        c: &Compute,
        p: Precision,
        _start: Cycle,
        _occ: &Occupancy,
    ) -> Result<TileCost> {
        fabric.tiles[tile].execute(c, p)
    }
}

/// Congestion knobs: transfer latency scales with the average number of
/// concurrently-resident transfer steps during the previous epoch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CongestionKnobs {
    /// Latency slope per average resident transfer.
    pub alpha: f64,
    /// Ceiling on the congestion factor.
    pub cap: f64,
}

impl Default for CongestionKnobs {
    fn default() -> Self {
        CongestionKnobs { alpha: 0.25, cap: 4.0 }
    }
}

/// DVFS/thermal knobs: discrete frequency throttle levels driven by the
/// tile's busy fraction over a trailing window of epochs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DvfsKnobs {
    /// Trailing window length, in epochs.
    pub window: u64,
    /// Busy fraction at/above which the tile throttles to `warm_scale`.
    pub warm_frac: f64,
    /// Busy fraction at/above which the tile throttles to `hot_scale`.
    pub hot_frac: f64,
    /// Frequency scale in the warm band (0 < scale <= 1).
    pub warm_scale: f64,
    /// Frequency scale in the hot band (0 < scale <= 1).
    pub hot_scale: f64,
}

impl Default for DvfsKnobs {
    fn default() -> Self {
        DvfsKnobs { window: 4, warm_frac: 0.6, hot_frac: 0.9, warm_scale: 0.75, hot_scale: 0.5 }
    }
}

/// The time-varying model family: congestion-aware link/HBM pricing
/// and/or DVFS/thermal tile pricing, both quantized to `epoch`-cycle
/// occupancy windows (strictly-earlier-epoch reads only — see module
/// docs for why that makes the fixed point unique).
#[derive(Debug, Clone, Copy)]
pub struct VaryingCost {
    /// Occupancy epoch length, cycles.
    pub epoch: Cycle,
    pub congestion: Option<CongestionKnobs>,
    pub dvfs: Option<DvfsKnobs>,
}

impl VaryingCost {
    /// Congestion-only model.
    pub fn congestion(epoch: Cycle, knobs: CongestionKnobs) -> Self {
        assert!(epoch > 0, "time-varying cost epoch must be positive");
        VaryingCost { epoch, congestion: Some(knobs), dvfs: None }
    }

    /// DVFS-only model.
    pub fn dvfs(epoch: Cycle, knobs: DvfsKnobs) -> Self {
        assert!(epoch > 0, "time-varying cost epoch must be positive");
        VaryingCost { epoch, congestion: None, dvfs: Some(knobs) }
    }

    /// Both mechanisms on one epoch grid.
    pub fn congestion_dvfs(epoch: Cycle, c: CongestionKnobs, d: DvfsKnobs) -> Self {
        assert!(epoch > 0, "time-varying cost epoch must be positive");
        VaryingCost { epoch, congestion: Some(c), dvfs: Some(d) }
    }

    /// Congestion latency factor at `start`: reads the previous epoch's
    /// resident-transfer integral (epoch 0 sees no history → 1.0).
    pub fn congestion_factor(&self, start: Cycle, occ: &Occupancy) -> f64 {
        let Some(k) = self.congestion else { return 1.0 };
        let e = start / self.epoch;
        if e == 0 || !occ.is_tracking() {
            return 1.0;
        }
        let resident = occ.transfer_cycles(e - 1) as f64 / self.epoch as f64;
        (1.0 + k.alpha * resident).min(k.cap)
    }

    /// DVFS frequency scale for `tile` at `start`: busy fraction over the
    /// trailing window of fully elapsed epochs, mapped to discrete
    /// throttle levels (1.0 when cool or without history).
    pub fn dvfs_scale(&self, tile: usize, start: Cycle, occ: &Occupancy) -> f64 {
        let Some(k) = self.dvfs else { return 1.0 };
        let e = start / self.epoch;
        if e == 0 || !occ.is_tracking() || k.window == 0 {
            return 1.0;
        }
        let w = k.window.min(e);
        let busy: u64 = (e - w..e).map(|j| occ.tile_busy_cycles(tile, j)).sum();
        let frac = busy as f64 / (w * self.epoch) as f64;
        if frac >= k.hot_frac {
            k.hot_scale
        } else if frac >= k.warm_frac {
            k.warm_scale
        } else {
            1.0
        }
    }
}

/// Stretch a latency by `factor >= 1.0` (ceil to whole cycles).
fn stretch(cycles: Cycle, factor: f64) -> Cycle {
    if factor == 1.0 {
        cycles
    } else {
        (cycles as f64 * factor).ceil() as Cycle
    }
}

impl CostModel for VaryingCost {
    fn time_dependence(&self) -> TimeDependence {
        // A knob-less instance is genuinely invariant — declare it so:
        // `name()`, the behavior class and the engines' invalidation
        // rule then all agree for every constructible value.
        if self.congestion.is_none() && self.dvfs.is_none() {
            TimeDependence::Invariant
        } else {
            TimeDependence::VaryingAfter(self.epoch)
        }
    }

    fn name(&self) -> &'static str {
        match (self.congestion.is_some(), self.dvfs.is_some()) {
            (true, true) => "congestion_dvfs",
            (true, false) => "congestion",
            (false, true) => "dvfs",
            (false, false) => "invariant",
        }
    }

    fn transport(
        &self,
        fabric: &Fabric,
        src: NodeId,
        dst: NodeId,
        bytes: u64,
        start: Cycle,
        occ: &Occupancy,
    ) -> Metrics {
        let mut m = fabric.transport(src, dst, bytes);
        m.cycles = stretch(m.cycles, self.congestion_factor(start, occ));
        m
    }

    fn feed(
        &self,
        fabric: &Fabric,
        tile: usize,
        bytes: u64,
        start: Cycle,
        occ: &Occupancy,
    ) -> Metrics {
        let mut m = fabric.feed(tile, bytes);
        m.cycles = stretch(m.cycles, self.congestion_factor(start, occ));
        m
    }

    fn execute(
        &self,
        fabric: &Fabric,
        tile: usize,
        c: &Compute,
        p: Precision,
        start: Cycle,
        occ: &Occupancy,
    ) -> Result<TileCost> {
        let mut cost = fabric.tiles[tile].execute(c, p)?;
        let scale = self.dvfs_scale(tile, start, occ);
        if scale != 1.0 {
            cost.metrics.cycles = (cost.metrics.cycles as f64 / scale).ceil() as Cycle;
        }
        Ok(cost)
    }
}

/// Exec-latency penalty factor a dead tile prices at. Large but
/// **finite**: while a fault's afflicted programs are being re-mapped
/// one at a time, the invalidation machinery may transiently re-price a
/// not-yet-re-mapped step on the dead tile — that price must exist (it
/// is always retracted before the schedule settles), it just must never
/// look attractive.
pub const DEAD_TILE_FACTOR: f64 = 1.0e6;

/// Latency factor of a failed (rerouting) link while the failure window
/// is active.
pub const LINK_FAIL_FACTOR: f64 = 8.0;

/// Default occupancy epoch a [`DegradedCost`] declares when its inner
/// model is time-invariant (any positive value is correct — degraded
/// pricing reads `start` only, never occupancy; the epoch merely sizes
/// the session's invalidation grid).
pub const DEGRADED_DEFAULT_EPOCH: Cycle = 256;

/// Fault-degraded pricing: wraps any [`CostModel`] and stretches
/// latencies according to a pre-materialized fault timeline (the pricing
/// half of [`crate::sim::FaultPlan`] — link degradation/failure, HBM
/// brownout, accelerator wear, dead-tile quarantine).
///
/// Every modifier is keyed by the step's **start** cycle: a step
/// starting inside an active window is stretched, a step merely spanning
/// one is not. That keeps the degraded price a pure function of
/// `(fabric, step, start, inner model)`, so the cost seam's purity and
/// strictly-earlier-epoch contracts hold exactly as for the inner model,
/// and the admission session's settle loop converges unchanged. Energy
/// is left unscaled (degradation stretches time in this model family,
/// matching [`VaryingCost`]'s convention).
///
/// Dead tiles are *quarantined by price*: any exec starting at/after the
/// death instant is stretched by [`DEAD_TILE_FACTOR`] — a safety net
/// under the recovery layer, which re-maps work off dead tiles anyway.
pub struct DegradedCost {
    inner: Arc<dyn CostModel>,
    /// Occupancy epoch declared when any modifier exists.
    epoch: Cycle,
    /// Per-tile death cycle (`Cycle::MAX` = alive).
    dead_at: Vec<Cycle>,
    /// Per-tile exec stretch windows `(start, end, factor)`.
    exec_mods: Vec<Vec<(Cycle, Cycle, f64)>>,
    /// Directional NoC-node-pair stretch windows
    /// `(src node, dst node, start, end, factor)` — directional because
    /// the admission session's link resources are ordered pairs.
    link_mods: Vec<(NodeId, NodeId, Cycle, Cycle, f64)>,
    /// HBM feed stretch windows `(start, end, factor)`.
    hbm_mods: Vec<(Cycle, Cycle, f64)>,
}

impl DegradedCost {
    /// Materialize `plan`'s pricing timeline over `fabric`, wrapping
    /// `inner`. The declared epoch is the inner model's (occupancy grids
    /// must agree), or [`DEGRADED_DEFAULT_EPOCH`] over an invariant
    /// inner model.
    pub fn from_plan(
        inner: Arc<dyn CostModel>,
        fabric: &Fabric,
        plan: &crate::sim::FaultPlan,
    ) -> Self {
        let nt = fabric.tile_count();
        let epoch = inner.time_dependence().epoch().unwrap_or(DEGRADED_DEFAULT_EPOCH);
        let mut dead_at = vec![Cycle::MAX; nt];
        let mut exec_mods = vec![Vec::new(); nt];
        let mut link_mods = Vec::new();
        let mut hbm_mods = Vec::new();
        for ev in plan.events() {
            match ev.kind {
                crate::sim::FaultKind::TileTransient { .. } => {}
                crate::sim::FaultKind::TileDeath { tile } => {
                    dead_at[tile] = dead_at[tile].min(ev.at);
                }
                crate::sim::FaultKind::LinkDegrade { from, to, factor, duration } => {
                    link_mods.push((
                        fabric.tiles[from].node,
                        fabric.tiles[to].node,
                        ev.at,
                        ev.at.saturating_add(duration),
                        factor,
                    ));
                }
                crate::sim::FaultKind::LinkFail { from, to, duration } => {
                    link_mods.push((
                        fabric.tiles[from].node,
                        fabric.tiles[to].node,
                        ev.at,
                        ev.at.saturating_add(duration),
                        LINK_FAIL_FACTOR,
                    ));
                }
                crate::sim::FaultKind::HbmBrownout { factor, duration } => {
                    hbm_mods.push((ev.at, ev.at.saturating_add(duration), factor));
                }
                crate::sim::FaultKind::CrossbarDrift { tile, factor, duration }
                | crate::sim::FaultKind::PhotonicThermal { tile, factor, duration } => {
                    exec_mods[tile].push((ev.at, ev.at.saturating_add(duration), factor));
                }
            }
        }
        DegradedCost { inner, epoch, dead_at, exec_mods, link_mods, hbm_mods }
    }

    /// The wrapped model.
    pub fn inner(&self) -> &Arc<dyn CostModel> {
        &self.inner
    }

    /// Death cycle of `tile` (`Cycle::MAX` = never dies in this plan).
    pub fn dead_at(&self, tile: usize) -> Cycle {
        self.dead_at[tile]
    }

    fn has_mods(&self) -> bool {
        !self.link_mods.is_empty()
            || !self.hbm_mods.is_empty()
            || self.exec_mods.iter().any(|m| !m.is_empty())
            || self.dead_at.iter().any(|&d| d != Cycle::MAX)
    }

    /// Product of the factors of every window containing `start`.
    fn window_factor(mods: &[(Cycle, Cycle, f64)], start: Cycle) -> f64 {
        let mut f = 1.0;
        for &(lo, hi, fac) in mods {
            if start >= lo && start < hi {
                f *= fac;
            }
        }
        f
    }

    /// Exec-latency factor of `tile` at `start` (wear windows × dead
    /// quarantine).
    pub fn exec_factor(&self, tile: usize, start: Cycle) -> f64 {
        let mut f = Self::window_factor(&self.exec_mods[tile], start);
        if start >= self.dead_at[tile] {
            f *= DEAD_TILE_FACTOR;
        }
        f
    }

    /// Transport-latency factor of the ordered node pair at `start`.
    pub fn link_factor(&self, src: NodeId, dst: NodeId, start: Cycle) -> f64 {
        let mut f = 1.0;
        for &(a, b, lo, hi, fac) in &self.link_mods {
            if a == src && b == dst && start >= lo && start < hi {
                f *= fac;
            }
        }
        f
    }

    /// HBM feed-latency factor at `start`.
    pub fn hbm_factor(&self, start: Cycle) -> f64 {
        Self::window_factor(&self.hbm_mods, start)
    }
}

impl CostModel for DegradedCost {
    fn time_dependence(&self) -> TimeDependence {
        if self.has_mods() {
            TimeDependence::VaryingAfter(self.epoch)
        } else {
            // Nothing prices differently: behave exactly as the inner
            // model (an inert wrapper must not force horizon
            // invalidation on an invariant session).
            self.inner.time_dependence()
        }
    }

    fn name(&self) -> &'static str {
        "degraded"
    }

    fn transport(
        &self,
        fabric: &Fabric,
        src: NodeId,
        dst: NodeId,
        bytes: u64,
        start: Cycle,
        occ: &Occupancy,
    ) -> Metrics {
        let mut m = self.inner.transport(fabric, src, dst, bytes, start, occ);
        m.cycles = stretch(m.cycles, self.link_factor(src, dst, start));
        m
    }

    fn feed(
        &self,
        fabric: &Fabric,
        tile: usize,
        bytes: u64,
        start: Cycle,
        occ: &Occupancy,
    ) -> Metrics {
        let mut m = self.inner.feed(fabric, tile, bytes, start, occ);
        m.cycles = stretch(m.cycles, self.hbm_factor(start));
        m
    }

    fn execute(
        &self,
        fabric: &Fabric,
        tile: usize,
        c: &Compute,
        p: Precision,
        start: Cycle,
        occ: &Occupancy,
    ) -> Result<TileCost> {
        let mut cost = self.inner.execute(fabric, tile, c, p, start, occ)?;
        cost.metrics.cycles = stretch(cost.metrics.cycles, self.exec_factor(tile, start));
        Ok(cost)
    }
}

/// Kind-aware pricing knobs (module docs, kind-aware pricing rules).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KindKnobs {
    /// Laser ramp-up + thermal-tuning latency a cold photonic tile pays.
    pub photonic_warmup_cycles: Cycle,
    /// Thermal-tuning energy of a cold start ([`Category::Laser`]).
    pub photonic_tuning_pj: f64,
    /// Trailing warm-state window, in epochs (the DVFS aggregates).
    pub photonic_window: u64,
    /// Busy fraction at/above which a photonic tile counts as warm.
    pub photonic_warm_frac: f64,
    /// Fixed ADC/DAC conversion latency per crossbar access.
    pub crossbar_access_cycles: Cycle,
    /// ADC/DAC conversion energy per operand byte crossing the analog
    /// boundary.
    pub crossbar_adc_pj_per_byte: f64,
    /// Wear slope per epoch-normalized cumulative busy integral.
    pub crossbar_wear_alpha: f64,
    /// Ceiling on the wear factor.
    pub crossbar_wear_cap: f64,
    /// Arithmetic intensity (ops/byte) at/below which a neuromorphic
    /// step prices as sparse.
    pub neuro_sparse_intensity: f64,
    /// Compute/leakage energy scale of a sparse spiking step.
    pub neuro_sparse_scale: f64,
    /// Compute/leakage energy scale of a dense spiking step.
    pub neuro_dense_scale: f64,
    /// HBM-feed [`Category::Dram`] energy scale of a `pim_dram` tile
    /// (< 1: the operands already live in the DRAM die; feed *time* is
    /// unchanged — bank bandwidth is not improved by proximity).
    pub pim_offload_scale: f64,
    /// DRAM-bank contention slope per average resident transfer.
    pub pim_contention_alpha: f64,
    /// Ceiling on the PIM contention factor.
    pub pim_contention_cap: f64,
}

impl Default for KindKnobs {
    fn default() -> Self {
        KindKnobs {
            photonic_warmup_cycles: 2_000,
            photonic_tuning_pj: 50_000.0,
            photonic_window: 4,
            photonic_warm_frac: 0.25,
            crossbar_access_cycles: 32,
            crossbar_adc_pj_per_byte: 2.0,
            crossbar_wear_alpha: 0.05,
            crossbar_wear_cap: 3.0,
            neuro_sparse_intensity: 2.0,
            neuro_sparse_scale: 0.75,
            neuro_dense_scale: 1.25,
            pim_offload_scale: 0.6,
            pim_contention_alpha: 0.25,
            pim_contention_cap: 4.0,
        }
    }
}

/// Rebuild `m` with each energy category scaled by `f(cat)` (the energy
/// map is append-only, so scaling below 1.0 needs a rebuild). Category
/// iteration is `BTreeMap` order and each category appears once, so the
/// result is deterministic.
fn scale_energy(m: &Metrics, f: impl Fn(Category) -> f64) -> Metrics {
    let mut out = Metrics::new();
    out.cycles = m.cycles;
    out.ops = m.ops;
    out.bytes_moved = m.bytes_moved;
    for (cat, pj) in m.breakdown() {
        out.add_energy(cat, pj * f(cat));
    }
    out
}

/// Kind-aware accelerator pricing (`[fabric.cost] model = "kind"`): the
/// per-device-class modifiers of the module docs' kind-aware pricing
/// rules, layered on the analytic fabric primitives. `npu` and `cpu`
/// tiles price exactly as [`InvariantCost`]; the post-CMOS kinds get
/// photonic warm-up, crossbar ADC/DAC + wear, neuromorphic spike-rate
/// energy, and PIM offload/contention pricing.
#[derive(Debug, Clone, Copy)]
pub struct KindCost {
    /// Occupancy epoch length, cycles.
    pub epoch: Cycle,
    pub knobs: KindKnobs,
}

impl KindCost {
    pub fn new(epoch: Cycle, knobs: KindKnobs) -> Self {
        assert!(epoch > 0, "kind-aware cost epoch must be positive");
        KindCost { epoch, knobs }
    }

    /// Build from a validated `[fabric.cost]` section: the shared
    /// epoch/window/threshold knobs come from the config, the per-kind
    /// constants keep their defaults.
    pub fn from_config(cfg: &CostConfig) -> Self {
        let knobs = KindKnobs {
            photonic_window: cfg.window_epochs,
            photonic_warm_frac: cfg.warm_frac,
            pim_contention_alpha: cfg.alpha,
            pim_contention_cap: cfg.cap,
            ..KindKnobs::default()
        };
        KindCost::new(cfg.epoch_cycles, knobs)
    }

    /// Is the photonic `tile` warm at `start`? Busy fraction over the
    /// trailing window of fully elapsed epochs, at/above the warm
    /// threshold. Epoch 0 / untracked occupancy is always cold.
    pub fn photonic_warm(&self, tile: usize, start: Cycle, occ: &Occupancy) -> bool {
        let e = start / self.epoch;
        if e == 0 || !occ.is_tracking() || self.knobs.photonic_window == 0 {
            return false;
        }
        let w = self.knobs.photonic_window.min(e);
        let busy: u64 = (e - w..e).map(|j| occ.tile_busy_cycles(tile, j)).sum();
        let frac = busy as f64 / (w * self.epoch) as f64;
        frac >= self.knobs.photonic_warm_frac
    }

    /// Crossbar wear factor at `start`: cumulative busy integral over
    /// **all** strictly earlier epochs (wear never heals), normalized by
    /// the epoch length — monotone nondecreasing in `start` for a fixed
    /// schedule.
    pub fn crossbar_wear_factor(&self, tile: usize, start: Cycle, occ: &Occupancy) -> f64 {
        let e = start / self.epoch;
        if e == 0 || !occ.is_tracking() {
            return 1.0;
        }
        let busy: u64 = (0..e).map(|j| occ.tile_busy_cycles(tile, j)).sum();
        let wear = busy as f64 / self.epoch as f64;
        (1.0 + self.knobs.crossbar_wear_alpha * wear).min(self.knobs.crossbar_wear_cap)
    }

    /// DRAM-bank contention factor a `pim_dram` exec pays at `start`:
    /// the previous epoch's resident-transfer integral, shaped exactly
    /// like [`VaryingCost::congestion_factor`].
    pub fn pim_contention_factor(&self, start: Cycle, occ: &Occupancy) -> f64 {
        let e = start / self.epoch;
        if e == 0 || !occ.is_tracking() {
            return 1.0;
        }
        let resident = occ.transfer_cycles(e - 1) as f64 / self.epoch as f64;
        (1.0 + self.knobs.pim_contention_alpha * resident).min(self.knobs.pim_contention_cap)
    }

    /// Spike-rate energy scale of one step: ops/byte at/below the sparse
    /// threshold gates idle neurons off, above it spike storms dominate.
    pub fn neuro_energy_scale(&self, c: &Compute, p: Precision) -> f64 {
        let intensity = c.ops() as f64 / c.io_bytes(p).max(1) as f64;
        if intensity <= self.knobs.neuro_sparse_intensity {
            self.knobs.neuro_sparse_scale
        } else {
            self.knobs.neuro_dense_scale
        }
    }
}

impl CostModel for KindCost {
    fn time_dependence(&self) -> TimeDependence {
        TimeDependence::VaryingAfter(self.epoch)
    }

    fn name(&self) -> &'static str {
        "kind"
    }

    fn transport(
        &self,
        fabric: &Fabric,
        src: NodeId,
        dst: NodeId,
        bytes: u64,
        _start: Cycle,
        _occ: &Occupancy,
    ) -> Metrics {
        fabric.transport(src, dst, bytes)
    }

    fn feed(
        &self,
        fabric: &Fabric,
        tile: usize,
        bytes: u64,
        _start: Cycle,
        _occ: &Occupancy,
    ) -> Metrics {
        let m = fabric.feed(tile, bytes);
        if fabric.tiles[tile].kind != TileKind::PimDram {
            return m;
        }
        // PIM offload: the feed's streaming half stays in the DRAM die,
        // so its DRAM energy is discounted. Time is untouched — bank
        // bandwidth is what it is, and keeping every kind modifier a
        // time *tax or par* is what makes the invariant estimate a
        // cycles floor (pinned in `tests/kindcost_golden.rs`).
        let scale = self.knobs.pim_offload_scale;
        scale_energy(&m, |cat| if cat == Category::Dram { scale } else { 1.0 })
    }

    fn execute(
        &self,
        fabric: &Fabric,
        tile: usize,
        c: &Compute,
        p: Precision,
        start: Cycle,
        occ: &Occupancy,
    ) -> Result<TileCost> {
        let mut cost = fabric.tiles[tile].execute(c, p)?;
        match fabric.tiles[tile].kind {
            TileKind::Npu | TileKind::Cpu => {}
            TileKind::Photonic => {
                if !self.photonic_warm(tile, start, occ) {
                    cost.metrics.cycles += self.knobs.photonic_warmup_cycles;
                    cost.metrics.add_energy(Category::Laser, self.knobs.photonic_tuning_pj);
                }
            }
            TileKind::Crossbar => {
                let wear = self.crossbar_wear_factor(tile, start, occ);
                cost.metrics.cycles =
                    stretch(cost.metrics.cycles + self.knobs.crossbar_access_cycles, wear);
                cost.metrics.add_energy(
                    Category::Adc,
                    c.io_bytes(p) as f64 * self.knobs.crossbar_adc_pj_per_byte * wear,
                );
            }
            TileKind::Neuromorphic => {
                let scale = self.neuro_energy_scale(c, p);
                cost.metrics = scale_energy(&cost.metrics, |cat| {
                    if matches!(cat, Category::Compute | Category::Leakage) {
                        scale
                    } else {
                        1.0
                    }
                });
            }
            TileKind::PimDram => {
                cost.metrics.cycles =
                    stretch(cost.metrics.cycles, self.pim_contention_factor(start, occ));
            }
        }
        Ok(cost)
    }
}

/// Build a *variant* of a configured cost model: same knobs
/// (epoch/congestion/DVFS/kind constants), different `model` selector.
/// This is the DSE sweep's model axis (`dse::sweep`): every candidate
/// shares the fabric's tuned constants and varies only the pricing
/// family, so rankings compare models rather than accidental knob
/// drift. Validates like [`model_from_config`].
pub fn model_variant(base: &CostConfig, model: &str) -> Result<Arc<dyn CostModel>> {
    let mut cfg = base.clone();
    cfg.model = model.to_string();
    model_from_config(&cfg)
}

/// Build the configured cost model (`[fabric.cost]`, see
/// [`crate::config::CostConfig`]). Re-validates the knobs so a
/// hand-built config cannot smuggle NaN/out-of-range values past the
/// TOML loader's checks.
pub fn model_from_config(cfg: &CostConfig) -> Result<Arc<dyn CostModel>> {
    cfg.validate()?;
    let cong = CongestionKnobs { alpha: cfg.alpha, cap: cfg.cap };
    let dvfs = DvfsKnobs {
        window: cfg.window_epochs,
        warm_frac: cfg.warm_frac,
        hot_frac: cfg.hot_frac,
        warm_scale: cfg.warm_scale,
        hot_scale: cfg.hot_scale,
    };
    Ok(match cfg.model.as_str() {
        "invariant" => Arc::new(InvariantCost),
        "congestion" => Arc::new(VaryingCost::congestion(cfg.epoch_cycles, cong)),
        "dvfs" => Arc::new(VaryingCost::dvfs(cfg.epoch_cycles, dvfs)),
        "congestion_dvfs" => {
            Arc::new(VaryingCost::congestion_dvfs(cfg.epoch_cycles, cong, dvfs))
        }
        "kind" => Arc::new(KindCost::from_config(cfg)),
        other => bail!("unknown cost model {other:?}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FabricConfig;

    fn fabric() -> Fabric {
        Fabric::build(
            FabricConfig::from_toml(
                "[noc]\nwidth = 3\nheight = 3\n\
                 [[cu]]\nkind = \"npu\"\ntemplate = \"B\"\ncount = 4\n",
            )
            .unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn occupancy_add_remove_roundtrips_exactly() {
        let mut o = Occupancy::new(100);
        o.add_transfer(50, 260); // epochs 0 (50), 1 (100), 2 (60)
        assert_eq!(o.transfer_cycles(0), 50);
        assert_eq!(o.transfer_cycles(1), 100);
        assert_eq!(o.transfer_cycles(2), 60);
        o.add_transfer(120, 130);
        assert_eq!(o.transfer_cycles(1), 110);
        o.remove_transfer(50, 260);
        assert_eq!(o.transfer_cycles(0), 0);
        assert_eq!(o.transfer_cycles(1), 10);
        assert_eq!(o.transfer_cycles(2), 0);
        o.remove_transfer(120, 130);
        assert!(o.transfer.is_empty(), "all counters drained to zero");
        // Zero-length spans are no-ops.
        o.add_transfer(7, 7);
        assert!(o.transfer.is_empty());
    }

    #[test]
    fn occupancy_tile_busy_per_tile_and_epoch() {
        let mut o = Occupancy::new(64);
        o.add_tile_busy(2, 0, 200); // epochs 0..=3
        assert_eq!(o.tile_busy_cycles(2, 0), 64);
        assert_eq!(o.tile_busy_cycles(2, 3), 200 - 3 * 64);
        assert_eq!(o.tile_busy_cycles(1, 0), 0);
        o.remove_tile_busy(2, 0, 200);
        assert!(o.tile_busy.is_empty());
    }

    #[test]
    fn disabled_occupancy_is_inert() {
        let mut o = Occupancy::disabled();
        assert!(!o.is_tracking());
        o.add_transfer(0, 1000);
        o.add_tile_busy(0, 0, 1000);
        assert_eq!(o.transfer_cycles(0), 0);
        assert_eq!(o.tile_busy_cycles(0, 0), 0);
    }

    #[test]
    fn invariant_model_matches_analytic_primitives_bitwise() {
        let f = fabric();
        let m = InvariantCost;
        let occ = Occupancy::disabled();
        let a = m.transport(&f, 0, 3, 4096, 12345, &occ);
        let b = f.transport(0, 3, 4096);
        assert_eq!(a, b);
        assert_eq!(a.total_energy_pj().to_bits(), b.total_energy_pj().to_bits());
        let a = m.feed(&f, 1, 4096, 999, &occ);
        let b = f.feed(1, 4096);
        assert_eq!(a, b);
        assert_eq!(m.time_dependence().epoch(), None);
    }

    #[test]
    fn congestion_reads_previous_epoch_only() {
        let f = fabric();
        let model = VaryingCost::congestion(100, CongestionKnobs { alpha: 0.5, cap: 4.0 });
        let mut occ = Occupancy::new(100);
        // Two transfers resident through all of epoch 0.
        occ.add_transfer(0, 100);
        occ.add_transfer(0, 100);
        let base = f.transport(0, 3, 4096);
        // Epoch 0 start: no history, base latency.
        assert_eq!(model.transport(&f, 0, 3, 4096, 0, &occ).cycles, base.cycles);
        assert_eq!(model.transport(&f, 0, 3, 4096, 99, &occ).cycles, base.cycles);
        // Epoch 1 start: reads epoch 0 (avg residency 2) -> factor 2.0.
        let congested = model.transport(&f, 0, 3, 4096, 100, &occ);
        assert_eq!(congested.cycles, (base.cycles as f64 * 2.0).ceil() as u64);
        // Energy is untouched by congestion.
        assert_eq!(
            congested.total_energy_pj().to_bits(),
            base.total_energy_pj().to_bits()
        );
        // Epoch 2 start: epoch 1 is empty -> back to base.
        assert_eq!(model.transport(&f, 0, 3, 4096, 200, &occ).cycles, base.cycles);
    }

    #[test]
    fn congestion_factor_caps() {
        let model = VaryingCost::congestion(10, CongestionKnobs { alpha: 1.0, cap: 3.0 });
        let mut occ = Occupancy::new(10);
        for _ in 0..50 {
            occ.add_transfer(0, 10);
        }
        assert_eq!(model.congestion_factor(10, &occ), 3.0);
    }

    #[test]
    fn dvfs_throttles_hot_tiles_with_discrete_levels() {
        let f = fabric();
        let knobs = DvfsKnobs {
            window: 2,
            warm_frac: 0.5,
            hot_frac: 0.9,
            warm_scale: 0.8,
            hot_scale: 0.5,
        };
        let model = VaryingCost::dvfs(100, knobs);
        let mut occ = Occupancy::new(100);
        let c = Compute::MatMul { m: 8, k: 8, n: 8 };
        let base = f.tiles[0].execute(&c, Precision::Int8).unwrap().metrics.cycles;
        // Cold tile: full speed.
        assert_eq!(model.dvfs_scale(0, 250, &occ), 1.0);
        // Tile 0 fully busy through epochs 0 and 1 -> hot at epoch 2.
        occ.add_tile_busy(0, 0, 200);
        assert_eq!(model.dvfs_scale(0, 250, &occ), 0.5);
        let throttled =
            model.execute(&f, 0, &c, Precision::Int8, 250, &occ).unwrap().metrics.cycles;
        assert_eq!(throttled, (base as f64 / 0.5).ceil() as u64);
        // Half busy -> warm level; other tiles unaffected.
        occ.remove_tile_busy(0, 0, 200);
        occ.add_tile_busy(0, 0, 100);
        assert_eq!(model.dvfs_scale(0, 250, &occ), 0.8);
        assert_eq!(model.dvfs_scale(1, 250, &occ), 1.0);
        // Epoch 0 has no elapsed history at all.
        assert_eq!(model.dvfs_scale(0, 50, &occ), 1.0);
    }

    #[test]
    fn degraded_with_empty_plan_is_bit_transparent() {
        let f = fabric();
        let plan = crate::sim::FaultPlan::empty();
        let d = DegradedCost::from_plan(Arc::new(InvariantCost), &f, &plan);
        // Inert wrapper: declares the inner model's time dependence.
        assert_eq!(d.time_dependence(), TimeDependence::Invariant);
        let occ = Occupancy::disabled();
        let a = d.transport(&f, 0, 3, 4096, 77, &occ);
        let b = InvariantCost.transport(&f, 0, 3, 4096, 77, &occ);
        assert_eq!(a, b);
        assert_eq!(a.total_energy_pj().to_bits(), b.total_energy_pj().to_bits());
        let a = d.feed(&f, 1, 4096, 77, &occ);
        let b = InvariantCost.feed(&f, 1, 4096, 77, &occ);
        assert_eq!(a, b);
        let c = Compute::MatMul { m: 8, k: 8, n: 8 };
        let a = d.execute(&f, 0, &c, Precision::Int8, 77, &occ).unwrap();
        let b = InvariantCost.execute(&f, 0, &c, Precision::Int8, 77, &occ).unwrap();
        assert_eq!(a.metrics, b.metrics);
    }

    #[test]
    fn degraded_windows_stretch_only_starts_inside() {
        use crate::sim::{FaultEvent, FaultKind, FaultPlan};
        let f = fabric();
        let plan = FaultPlan::from_events(vec![
            FaultEvent { at: 1000, kind: FaultKind::HbmBrownout { factor: 1.5, duration: 500 } },
            FaultEvent {
                at: 2000,
                kind: FaultKind::LinkDegrade { from: 0, to: 1, factor: 2.0, duration: 100 },
            },
        ]);
        let d = DegradedCost::from_plan(Arc::new(InvariantCost), &f, &plan);
        assert_eq!(d.time_dependence(), TimeDependence::VaryingAfter(DEGRADED_DEFAULT_EPOCH));
        let occ = Occupancy::disabled();
        let base_feed = f.feed(1, 4096);
        // Before, inside, at-end, after the brownout window.
        assert_eq!(d.feed(&f, 1, 4096, 999, &occ).cycles, base_feed.cycles);
        assert_eq!(
            d.feed(&f, 1, 4096, 1000, &occ).cycles,
            (base_feed.cycles as f64 * 1.5).ceil() as u64
        );
        assert_eq!(d.feed(&f, 1, 4096, 1500, &occ).cycles, base_feed.cycles);
        // Energy untouched.
        assert_eq!(
            d.feed(&f, 1, 4096, 1200, &occ).total_energy_pj().to_bits(),
            base_feed.total_energy_pj().to_bits()
        );
        // Link mod is directional and node-pair keyed.
        let (s, t) = (f.tiles[0].node, f.tiles[1].node);
        let base = f.transport(s, t, 1024);
        assert_eq!(
            d.transport(&f, s, t, 1024, 2050, &occ).cycles,
            (base.cycles as f64 * 2.0).ceil() as u64
        );
        let rev = f.transport(t, s, 1024);
        assert_eq!(d.transport(&f, t, s, 1024, 2050, &occ).cycles, rev.cycles);
        assert_eq!(d.transport(&f, s, t, 1024, 2100, &occ).cycles, base.cycles);
    }

    #[test]
    fn degraded_quarantines_dead_tiles_with_finite_penalty() {
        use crate::sim::{FaultEvent, FaultKind, FaultPlan};
        let f = fabric();
        let plan = FaultPlan::from_events(vec![FaultEvent {
            at: 500,
            kind: FaultKind::TileDeath { tile: 2 },
        }]);
        let d = DegradedCost::from_plan(Arc::new(InvariantCost), &f, &plan);
        assert_eq!(d.dead_at(2), 500);
        assert_eq!(d.dead_at(0), Cycle::MAX);
        let occ = Occupancy::disabled();
        let c = Compute::MatMul { m: 4, k: 4, n: 4 };
        let base = d.execute(&f, 2, &c, Precision::Int8, 499, &occ).unwrap().metrics.cycles;
        let dead = d.execute(&f, 2, &c, Precision::Int8, 500, &occ).unwrap().metrics.cycles;
        assert_eq!(dead, (base as f64 * DEAD_TILE_FACTOR).ceil() as u64);
        assert!(dead < Cycle::MAX / 1024, "penalty must stay far from overflow");
        // Other tiles price normally.
        let other = d.execute(&f, 0, &c, Precision::Int8, 500, &occ).unwrap().metrics.cycles;
        assert_eq!(other, base);
    }

    #[test]
    fn degraded_wear_compounds_overlapping_windows() {
        use crate::sim::{FaultEvent, FaultKind, FaultPlan};
        let f = fabric();
        let plan = FaultPlan::from_events(vec![
            FaultEvent {
                at: 0,
                kind: FaultKind::CrossbarDrift { tile: 1, factor: 1.25, duration: 1000 },
            },
            FaultEvent {
                at: 500,
                kind: FaultKind::PhotonicThermal { tile: 1, factor: 1.5, duration: 1000 },
            },
        ]);
        let d = DegradedCost::from_plan(Arc::new(InvariantCost), &f, &plan);
        assert_eq!(d.exec_factor(1, 250), 1.25);
        assert_eq!(d.exec_factor(1, 750), 1.25 * 1.5);
        assert_eq!(d.exec_factor(1, 1200), 1.5);
        assert_eq!(d.exec_factor(1, 1500), 1.0);
    }

    #[test]
    fn model_variant_shares_knobs_and_validates() {
        let base = CostConfig { epoch_cycles: 512, ..CostConfig::default() };
        let m = model_variant(&base, "congestion").unwrap();
        assert_eq!(m.name(), "congestion");
        assert_eq!(m.time_dependence().epoch(), Some(512), "knobs must carry over");
        assert_eq!(model_variant(&base, "invariant").unwrap().name(), "invariant");
        assert!(model_variant(&base, "nonsense").is_err());
    }

    #[test]
    fn model_from_config_rejects_bad_knobs() {
        let cfg = CostConfig { alpha: f64::NAN, model: "congestion".into(), ..CostConfig::default() };
        let err = model_from_config(&cfg).unwrap_err().to_string();
        assert!(err.contains("alpha"), "error must name the key: {err}");
    }

    #[test]
    fn model_from_config_selects_and_rejects() {
        let mut cfg = CostConfig::default();
        assert_eq!(model_from_config(&cfg).unwrap().name(), "invariant");
        cfg.model = "congestion".into();
        let m = model_from_config(&cfg).unwrap();
        assert_eq!(m.name(), "congestion");
        assert_eq!(m.time_dependence().epoch(), Some(cfg.epoch_cycles));
        cfg.model = "dvfs".into();
        assert_eq!(model_from_config(&cfg).unwrap().name(), "dvfs");
        cfg.model = "congestion_dvfs".into();
        assert_eq!(model_from_config(&cfg).unwrap().name(), "congestion_dvfs");
        cfg.model = "kind".into();
        let m = model_from_config(&cfg).unwrap();
        assert_eq!(m.name(), "kind");
        assert_eq!(m.time_dependence().epoch(), Some(cfg.epoch_cycles));
        cfg.model = "quantum".into();
        assert!(model_from_config(&cfg).is_err());
    }

    /// One tile of every config kind on a 3x3 mesh (tile index order:
    /// npu, crossbar, photonic, neuromorphic, pim_dram, cpu).
    fn mixed_fabric() -> Fabric {
        Fabric::build(
            FabricConfig::from_toml(
                "[noc]\nwidth = 3\nheight = 3\n\
                 [[cu]]\nkind = \"npu\"\ntemplate = \"B\"\ncount = 1\n\
                 [[cu]]\nkind = \"crossbar\"\ntemplate = \"A\"\ncount = 1\n\
                 [[cu]]\nkind = \"photonic\"\ntemplate = \"A\"\ncount = 1\n\
                 [[cu]]\nkind = \"neuromorphic\"\ntemplate = \"A\"\ncount = 1\n\
                 [[cu]]\nkind = \"pim_dram\"\ntemplate = \"A\"\ncount = 1\n\
                 [[cu]]\nkind = \"cpu\"\ntemplate = \"C\"\ncount = 1\n",
            )
            .unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn kind_photonic_cold_start_pays_warmup_then_warms_up() {
        let f = mixed_fabric();
        let model = KindCost::new(100, KindKnobs::default());
        let c = Compute::MatMul { m: 8, k: 8, n: 8 };
        let base = f.tiles[2].execute(&c, Precision::Analog).unwrap().metrics;
        let mut occ = Occupancy::new(100);
        // No history: cold at every epoch, warm-up latency + tuning energy.
        let cold = model.execute(&f, 2, &c, Precision::Analog, 500, &occ).unwrap().metrics;
        assert_eq!(cold.cycles, base.cycles + model.knobs.photonic_warmup_cycles);
        assert_eq!(
            cold.energy(Category::Laser).to_bits(),
            (base.energy(Category::Laser) + model.knobs.photonic_tuning_pj).to_bits()
        );
        // Epoch 0 is cold by definition even with a tracking occupancy.
        assert!(!model.photonic_warm(2, 50, &occ));
        // Recent busy history above the warm fraction: base price, bitwise.
        occ.add_tile_busy(2, 100, 400);
        let warm = model.execute(&f, 2, &c, Precision::Analog, 500, &occ).unwrap().metrics;
        assert_eq!(warm, base);
        assert_eq!(warm.total_energy_pj().to_bits(), base.total_energy_pj().to_bits());
        // Other kinds never read the photonic warm state.
        assert!(!model.photonic_warm(2, 500, &Occupancy::disabled()));
    }

    #[test]
    fn kind_crossbar_wear_is_monotone_and_prices_adc() {
        let f = mixed_fabric();
        let model = KindCost::new(100, KindKnobs::default());
        let c = Compute::MatMul { m: 8, k: 8, n: 8 };
        let base = f.tiles[1].execute(&c, Precision::Analog).unwrap().metrics;
        let occ0 = Occupancy::new(100);
        // Fresh device: access overhead + per-byte ADC energy, no wear.
        let fresh = model.execute(&f, 1, &c, Precision::Analog, 500, &occ0).unwrap().metrics;
        assert_eq!(fresh.cycles, base.cycles + model.knobs.crossbar_access_cycles);
        let io = c.io_bytes(Precision::Analog) as f64;
        assert_eq!(
            fresh.energy(Category::Adc).to_bits(),
            (base.energy(Category::Adc) + io * model.knobs.crossbar_adc_pj_per_byte).to_bits()
        );
        // Wear accumulates over *all* earlier epochs and never heals:
        // the factor is nondecreasing in start for a fixed schedule.
        let mut occ = Occupancy::new(100);
        occ.add_tile_busy(1, 0, 600);
        let mut last = 1.0;
        for e in 1..8u64 {
            let w = model.crossbar_wear_factor(1, e * 100, &occ);
            assert!(w >= last, "wear healed: {w} < {last} at epoch {e}");
            last = w;
        }
        assert!(last > 1.0, "wear never bit");
        assert!(last <= model.knobs.crossbar_wear_cap);
        let worn = model.execute(&f, 1, &c, Precision::Analog, 700, &occ).unwrap().metrics;
        assert!(worn.cycles > fresh.cycles, "wear must stretch latency");
        assert!(
            worn.energy(Category::Adc) > fresh.energy(Category::Adc),
            "wear must degrade energy too"
        );
    }

    #[test]
    fn kind_neuromorphic_prices_spike_rate_from_op_byte_mix() {
        let f = mixed_fabric();
        let model = KindCost::new(100, KindKnobs::default());
        let occ = Occupancy::disabled();
        // intensity = ops/io_bytes = 8 * activity for a spiking layer.
        let sparse_c = Compute::SpikingLayer { synapses: 64 * 1024, activity: 0.1 };
        let dense_c = Compute::SpikingLayer { synapses: 64 * 1024, activity: 0.9 };
        assert_eq!(model.neuro_energy_scale(&sparse_c, Precision::Analog), 0.75);
        assert_eq!(model.neuro_energy_scale(&dense_c, Precision::Analog), 1.25);
        let base = f.tiles[3].execute(&sparse_c, Precision::Analog).unwrap().metrics;
        let sparse = model.execute(&f, 3, &sparse_c, Precision::Analog, 0, &occ).unwrap().metrics;
        // Time untouched; compute energy gated down, the rest unchanged.
        assert_eq!(sparse.cycles, base.cycles);
        assert_eq!(
            sparse.energy(Category::Compute).to_bits(),
            (base.energy(Category::Compute) * 0.75).to_bits()
        );
        assert_eq!(sparse.energy(Category::Noc).to_bits(), base.energy(Category::Noc).to_bits());
        let dense_base = f.tiles[3].execute(&dense_c, Precision::Analog).unwrap().metrics;
        let dense = model.execute(&f, 3, &dense_c, Precision::Analog, 0, &occ).unwrap().metrics;
        assert_eq!(
            dense.energy(Category::Compute).to_bits(),
            (dense_base.energy(Category::Compute) * 1.25).to_bits()
        );
    }

    #[test]
    fn kind_pim_discounts_feed_and_prices_bank_contention() {
        let f = mixed_fabric();
        let model = KindCost::new(100, KindKnobs::default());
        let occ = Occupancy::new(100);
        // Feed discount: PIM tile saves DRAM energy (time untouched —
        // the invariant cycles floor), non-PIM tiles delegate bitwise.
        let base = f.feed(4, 4096);
        let pim = model.feed(&f, 4, 4096, 0, &occ);
        assert_eq!(pim.cycles, base.cycles);
        assert_eq!(
            pim.energy(Category::Dram).to_bits(),
            (base.energy(Category::Dram) * model.knobs.pim_offload_scale).to_bits()
        );
        assert_eq!(pim.energy(Category::Noc).to_bits(), base.energy(Category::Noc).to_bits());
        assert_eq!(model.feed(&f, 0, 4096, 0, &occ), f.feed(0, 4096));
        // Exec contention: previous epoch's transfer residency stretches
        // PIM exec latency, congestion-factor shape.
        let mut busy = Occupancy::new(100);
        busy.add_transfer(0, 100);
        busy.add_transfer(0, 100);
        let c = Compute::MatMul { m: 8, k: 8, n: 8 };
        let calm = model.execute(&f, 4, &c, Precision::Analog, 0, &busy).unwrap().metrics;
        let contended = model.execute(&f, 4, &c, Precision::Analog, 100, &busy).unwrap().metrics;
        assert_eq!(contended.cycles, (calm.cycles as f64 * 1.5).ceil() as u64);
        assert_eq!(model.pim_contention_factor(200, &busy), 1.0, "epoch 1 is empty");
    }

    #[test]
    fn kind_model_leaves_digital_tiles_invariant() {
        let f = mixed_fabric();
        let model = KindCost::new(100, KindKnobs::default());
        let mut occ = Occupancy::new(100);
        occ.add_tile_busy(0, 0, 500);
        occ.add_tile_busy(5, 0, 500);
        occ.add_transfer(0, 500);
        let c = Compute::MatMul { m: 8, k: 8, n: 8 };
        for t in [0usize, 5] {
            let base = f.tiles[t].execute(&c, Precision::Int8).unwrap().metrics;
            let priced = model.execute(&f, t, &c, Precision::Int8, 900, &occ).unwrap().metrics;
            assert_eq!(priced, base);
            assert_eq!(priced.total_energy_pj().to_bits(), base.total_energy_pj().to_bits());
        }
        // Transport is kind-blind in this family.
        assert_eq!(model.transport(&f, 0, 3, 4096, 900, &occ), f.transport(0, 3, 4096));
    }
}
