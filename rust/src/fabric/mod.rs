//! The ARCHYTAS Scalable Compute Fabric (paper Fig. 1): heterogeneous
//! Compute Units on a NoC, with HBM at the edge.
//!
//! Three integration templates, exactly as the figure draws them:
//! * **A** — stand-alone accelerator with a bare NoC interface: every
//!   operand crosses the NoC per invocation, host-managed.
//! * **B** — accelerator wrapped with a RISC-V controller core, local
//!   TCDM and a DMA engine: double-buffered operand staging overlaps
//!   transfers with compute.
//! * **C** — accelerator(s) inside a PULP-style multi-core cluster:
//!   template B plus parallel cores that absorb elementwise/pre/post
//!   work, at higher control overhead and TCDM banking contention.

mod cluster;
pub mod cost;
mod dma;
mod hbm;
mod tile;

pub use cluster::PulpCluster;
pub use cost::{
    CongestionKnobs, CostModel, DegradedCost, DvfsKnobs, InvariantCost, KindCost, KindKnobs,
    Occupancy, TimeDependence, VaryingCost,
};
pub use dma::Dma;
pub use hbm::Hbm;
pub use tile::{Template, Tile, TileCost, TileKind};

use std::sync::Arc;

use anyhow::bail;

use crate::accel::{Accelerator, CpuCore, CrossbarNvm, DigitalNpu, Neuromorphic, Photonic};
use crate::config::FabricConfig;
use crate::metrics::{Area, Category, Metrics};
use crate::noc::{NodeId, Topology};
use crate::Result;

/// A built fabric instance: topology + placed tiles + memory, plus the
/// configured [`CostModel`] every start-time-aware resource query of the
/// co-simulation stack routes through (`[fabric.cost]`).
pub struct Fabric {
    pub cfg: FabricConfig,
    pub topo: Topology,
    pub tiles: Vec<Tile>,
    pub hbm: Hbm,
    /// NoC node hosting the HBM controller / host bridge.
    pub hbm_node: NodeId,
    /// Configured cost model (engines may override per run/session).
    cost: Arc<dyn CostModel>,
}

/// Construct the accelerator model for a config kind string.
pub fn make_accelerator(kind: &str) -> Result<Box<dyn Accelerator>> {
    Ok(match kind {
        "npu" => Box::new(DigitalNpu::default()),
        "crossbar" | "pim_dram" => Box::new(CrossbarNvm::default()),
        "photonic" => Box::new(Photonic::default()),
        "neuromorphic" => Box::new(Neuromorphic::default()),
        "cpu" => Box::new(CpuCore::default()),
        other => bail!("unknown accelerator kind {other:?}"),
    })
}

impl Fabric {
    /// Build from a validated config. Tiles are placed round-robin on NoC
    /// nodes 1.., node 0 hosts the HBM bridge.
    pub fn build(cfg: FabricConfig) -> Result<Self> {
        cfg.validate()?;
        let topo = Topology::from_config(&cfg.noc)?;
        Self::assemble(cfg, topo)
    }

    /// Build over an **explicit** topology — the DSE engine seam
    /// (`dse::explorer`'s co-sim refinement): candidate topologies may
    /// be shapes a `[noc]` section cannot express (rings, fat-trees,
    /// chordal customs), so `cfg.noc` contributes only link/router
    /// parameters here and the topology object is taken as-is. The
    /// config is still structurally validated; capacity is checked
    /// against the real node count during placement.
    pub fn build_with_topology(cfg: FabricConfig, topo: Topology) -> Result<Self> {
        cfg.validate()?;
        anyhow::ensure!(topo.is_connected(), "candidate topology is disconnected");
        Self::assemble(cfg, topo)
    }

    /// Shared placement tail of the two builders: tiles round-robin on
    /// nodes 1.., node 0 hosts the HBM bridge.
    fn assemble(cfg: FabricConfig, topo: Topology) -> Result<Self> {
        let mut tiles = Vec::new();
        let mut node = 1usize;
        for group in &cfg.cus {
            for _ in 0..group.count {
                if node >= topo.nodes() {
                    bail!("ran out of NoC nodes placing CUs");
                }
                let accel = make_accelerator(&group.kind)?;
                let kind = TileKind::from_config_str(&group.kind)
                    .ok_or_else(|| anyhow::anyhow!("unknown CU kind {:?}", group.kind))?;
                let template = Template::from_char(group.template)?;
                tiles.push(Tile::new(
                    tiles.len(),
                    node,
                    accel,
                    kind,
                    template,
                    group.tcdm_kb * 1024,
                    group.cluster_cores,
                ));
                node += 1;
            }
        }
        let hbm = Hbm::new(cfg.hbm_channels, cfg.hbm_bandwidth_gbps, cfg.hbm_energy_pj_per_byte);
        let cost = cost::model_from_config(&cfg.cost)?;
        Ok(Fabric { cfg, topo, tiles, hbm, hbm_node: 0, cost })
    }

    /// The configured cost model (`[fabric.cost]`; [`InvariantCost`] by
    /// default). Engines price through this unless handed an explicit
    /// model (`coordinator::exec::cosim_with`,
    /// `coordinator::admit::CosimSession::with_model`).
    pub fn cost_model(&self) -> &Arc<dyn CostModel> {
        &self.cost
    }

    /// Total silicon area (tiles + NoC routers at 0.05 mm² each + HBM phy).
    pub fn total_area(&self) -> Area {
        let tiles: Area = self.tiles.iter().map(Tile::area).sum();
        let routers = Area::new(self.topo.nodes() as f64 * 0.05);
        let hbm_phy = Area::new(self.cfg.hbm_channels as f64 * 0.8);
        tiles + routers + hbm_phy
    }

    /// Analytic NoC transport cost for `bytes` from node `src` to `dst`:
    /// serialization at link bandwidth + per-hop pipeline latency, energy
    /// per bit-hop (FlooNoC-calibrated). This is the **time-invariant
    /// pricing primitive**: the mapper estimates with it directly, and
    /// [`InvariantCost`] delegates to it bit-for-bit. Start-time-aware
    /// pricing lives one layer up, in [`cost::CostModel`] — the engines
    /// never call this directly anymore. E2 cross-checks the constants
    /// against the flit-level simulator.
    pub fn transport(&self, src: NodeId, dst: NodeId, bytes: u64) -> Metrics {
        let mut m = Metrics::new();
        if src == dst || bytes == 0 {
            return m;
        }
        let hops = self.topo.distances(src)[dst] as u64;
        debug_assert!(hops != u64::MAX as u64, "unreachable nodes");
        let noc = &self.cfg.noc;
        // Serialization: bytes over one link at link_bandwidth (bits/s)
        // expressed in fabric cycles.
        let link_bytes_per_cycle =
            noc.link_bandwidth_gbps / 8.0 / self.cfg.freq_ghz; // GB/s / GHz = B/cycle
        let ser = (bytes as f64 / link_bytes_per_cycle).ceil() as u64;
        m.cycles = hops * noc.router_latency_cycles + ser;
        m.bytes_moved = bytes;
        m.add_energy(
            Category::Noc,
            bytes as f64 * 8.0 * noc.hop_energy_pj_per_bit * hops as f64,
        );
        m
    }

    /// Transport from HBM to a tile (channel access + NoC leg) — the
    /// time-invariant feed primitive ([`InvariantCost`] delegates here).
    pub fn feed(&self, tile: usize, bytes: u64) -> Metrics {
        let mut m = self.hbm.access(bytes);
        let t = self.transport(self.hbm_node, self.tiles[tile].node, bytes);
        // HBM access and NoC transfer pipeline: latency = max + overlap
        // fudge (serial command, streamed data) — we take the sum of
        // fixed latencies and the max of the streaming parts, which the
        // simple model folds into addition of the smaller term's setup.
        m.cycles = m.cycles.max(t.cycles);
        m.absorb_parallel(&t);
        m
    }

    pub fn tile_count(&self) -> usize {
        self.tiles.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> FabricConfig {
        FabricConfig::from_toml(
            r#"
[noc]
topology = "mesh"
width = 4
height = 4

[[cu]]
kind = "npu"
template = "B"
count = 4

[[cu]]
kind = "crossbar"
template = "A"
count = 2

[[cu]]
kind = "cpu"
template = "C"
count = 1
cluster_cores = 8
"#,
        )
        .unwrap()
    }

    #[test]
    fn builds_and_places_tiles() {
        let f = Fabric::build(cfg()).unwrap();
        assert_eq!(f.tile_count(), 7);
        // nodes 1..=7, node 0 = HBM
        assert!(f.tiles.iter().all(|t| t.node != f.hbm_node));
        let nodes: std::collections::HashSet<_> = f.tiles.iter().map(|t| t.node).collect();
        assert_eq!(nodes.len(), 7, "one tile per node");
        assert!(f.total_area().mm2 > 0.0);
        assert_eq!(f.tiles[0].kind, TileKind::Npu);
        assert_eq!(f.tiles[4].kind, TileKind::Crossbar);
        assert_eq!(f.tiles[6].kind, TileKind::Cpu);
    }

    #[test]
    fn rejects_overfull() {
        let mut c = cfg();
        c.cus[0].count = 20;
        assert!(Fabric::build(c).is_err());
    }

    #[test]
    fn transport_scales_with_hops_and_bytes() {
        let f = Fabric::build(cfg()).unwrap();
        let near = f.transport(0, 1, 1024);
        let far = f.transport(0, 15, 1024);
        assert!(far.cycles > near.cycles);
        assert!(far.total_energy_pj() > near.total_energy_pj());
        let big = f.transport(0, 1, 64 * 1024);
        assert!(big.cycles > near.cycles * 10);
        assert_eq!(f.transport(3, 3, 1024).cycles, 0);
    }

    #[test]
    fn unknown_kind_rejected() {
        assert!(make_accelerator("tpu-v7").is_err());
    }

    #[test]
    fn build_with_topology_places_on_custom_shapes() {
        // A chordal ring is inexpressible as [noc]; the explicit-topology
        // builder must place the same tiles on it, and reject shapes too
        // small for the CU set.
        let ring = Topology::ring(16).unwrap();
        let f = Fabric::build_with_topology(cfg(), ring).unwrap();
        assert_eq!(f.tile_count(), 7);
        assert!(f.tiles.iter().all(|t| t.node != f.hbm_node));
        assert_eq!(f.topo.nodes(), 16);
        let tiny = Topology::ring(4).unwrap();
        assert!(Fabric::build_with_topology(cfg(), tiny).is_err());
    }
}
