//! Minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! The offline build image cannot reach crates.io, so this shim provides
//! exactly the surface archytas uses, with matching semantics:
//!
//! * [`Error`] — a context chain over an erased root cause. `{}` displays
//!   the outermost message only; `{:#}` joins the whole chain with `": "`
//!   (outermost first), like real `anyhow`.
//! * [`Result`] — `Result<T, Error>` with a defaulted error parameter.
//! * [`anyhow!`], [`bail!`], [`ensure!`] — format-style constructors.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` (for
//!   both std errors and [`Error`] itself) and on `Option`.
//! * `From<E>` for every `E: std::error::Error + Send + Sync + 'static`,
//!   so `?` conversions work unchanged.
//!
//! Deliberately not implemented (unused in this repo): downcasting,
//! backtraces, `Error::source` chains beyond message capture.

use std::convert::Infallible;
use std::fmt::{self, Debug, Display};

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error message chain: `chain[0]` is the outermost context, the last
/// entry is the root cause. Like real `anyhow::Error`, this type does
/// **not** implement `std::error::Error` (that is what makes the blanket
/// `From` impl coherent).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message.
    pub fn msg<M: Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context/cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> + '_ {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().expect("error chain is never empty")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            writeln!(f, "\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                writeln!(f, "    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        // Capture the typed error's own source chain as messages.
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Conversion helper so [`Context`] works uniformly for `Result<T, E>`
/// with `E` a std error *or* already an [`Error`]. The two impls do not
/// overlap because [`Error`] does not implement `std::error::Error`.
pub trait IntoShimError {
    fn into_shim_error(self) -> Error;
}

impl IntoShimError for Error {
    fn into_shim_error(self) -> Error {
        self
    }
}

impl<E> IntoShimError for E
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn into_shim_error(self) -> Error {
        Error::from(self)
    }
}

/// `.context(..)` / `.with_context(..)` extension for `Result` and
/// `Option`, mirroring `anyhow::Context`.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: IntoShimError,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into_shim_error().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_shim_error().context(f()))
    }
}

impl<T> Context<T, Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(msg: &str) -> Result<()> {
        bail!("root: {msg}")
    }

    #[test]
    fn display_outer_only_alternate_full_chain() {
        let e = fails("x").unwrap_err().context("outer");
        assert_eq!(e.to_string(), "outer");
        assert_eq!(format!("{e:#}"), "outer: root: x");
        assert_eq!(e.root_cause(), "root: x");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<()> = fails("y").context("ctx");
        assert_eq!(format!("{:#}", r.unwrap_err()), "ctx: root: y");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
        assert_eq!(Some(3).context("unused").unwrap(), 3);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
        let e = "nope".parse::<i32>().with_context(|| "bad flag").unwrap_err();
        assert!(format!("{e:#}").starts_with("bad flag: "));
    }

    #[test]
    fn ensure_both_arms() {
        fn check(v: usize) -> Result<()> {
            ensure!(v < 10);
            ensure!(v != 3, "three is right out (got {v})");
            Ok(())
        }
        assert!(check(2).is_ok());
        assert!(check(3).unwrap_err().to_string().contains("three"));
        assert!(check(11).unwrap_err().to_string().contains("condition failed"));
    }

    #[test]
    fn debug_lists_causes() {
        let e = fails("deep").unwrap_err().context("mid").context("top");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("top"));
        assert!(dbg.contains("Caused by"));
        assert!(dbg.contains("root: deep"));
    }
}
