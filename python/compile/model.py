"""L2: JAX model definitions for the ARCHYTAS workloads.

A ViT-tiny encoder (the paper's Sec. V.B headline workload class: Vision
Transformers on edge devices) and an MLP classifier, each instantiable on
three compute backends that mirror the fabric's CU types:

  * ``digital``   — plain f32 matmuls (the GPP / digital-NPU fallback),
  * ``npu_int8``  — dynamic INT8 quantization through the qmatmul Pallas
                    kernel (digital NPU tile, Sec. V.B dynamic quantization),
  * ``analog``    — the crossbar Pallas kernel with level-quantized
                    weights, read noise and ADC read-out (NVM-PIM /
                    photonic tile, Sec. II).

Weights are generated deterministically from a seed and *baked into the
lowered HLO as constants*; the AOT artifacts therefore take only the input
batch, which is what the Rust coordinator feeds at runtime. Python never
runs on the request path.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernels import crossbar, qmatmul, ref


@dataclasses.dataclass(frozen=True)
class ViTConfig:
    img: int = 16
    patch: int = 4
    in_chans: int = 3
    dim: int = 64
    depth: int = 2
    heads: int = 4
    mlp_ratio: int = 2
    classes: int = 10

    @property
    def tokens(self) -> int:
        return (self.img // self.patch) ** 2

    @property
    def patch_dim(self) -> int:
        return self.patch * self.patch * self.in_chans


@dataclasses.dataclass(frozen=True)
class MlpConfig:
    inputs: int = 256
    hidden: tuple = (128, 64)
    classes: int = 10


# Analog backend constants (kept in sync with rust/src/accel/pim_nvm.rs).
ANALOG_W_BITS = 6
ANALOG_ADC_BITS = 8
ANALOG_TILE_K = 32
ANALOG_NOISE_SIGMA = 0.0  # baked model is noise-free; noise swept in tests
ANALOG_X_ABSMAX = 4.0  # post-LayerNorm activations; calibration constant


# ---------------------------------------------------------------------------
# Parameter initialisation (deterministic)
# ---------------------------------------------------------------------------


def _dense_init(key, fan_in, fan_out):
    w = jax.random.normal(key, (fan_in, fan_out), jnp.float32)
    return w * (2.0 / (fan_in + fan_out)) ** 0.5


def init_vit(cfg: ViTConfig, seed: int = 0):
    """Returns a flat dict name -> array of all ViT parameters."""
    key = jax.random.PRNGKey(seed)
    params = {}

    def nxt():
        nonlocal key
        key, sub = jax.random.split(key)
        return sub

    params["embed/w"] = _dense_init(nxt(), cfg.patch_dim, cfg.dim)
    params["embed/b"] = jnp.zeros((cfg.dim,), jnp.float32)
    params["pos"] = 0.02 * jax.random.normal(
        nxt(), (cfg.tokens, cfg.dim), jnp.float32)
    for i in range(cfg.depth):
        p = f"block{i}/"
        params[p + "ln1/g"] = jnp.ones((cfg.dim,), jnp.float32)
        params[p + "ln1/b"] = jnp.zeros((cfg.dim,), jnp.float32)
        params[p + "qkv/w"] = _dense_init(nxt(), cfg.dim, 3 * cfg.dim)
        params[p + "qkv/b"] = jnp.zeros((3 * cfg.dim,), jnp.float32)
        params[p + "proj/w"] = _dense_init(nxt(), cfg.dim, cfg.dim)
        params[p + "proj/b"] = jnp.zeros((cfg.dim,), jnp.float32)
        params[p + "ln2/g"] = jnp.ones((cfg.dim,), jnp.float32)
        params[p + "ln2/b"] = jnp.zeros((cfg.dim,), jnp.float32)
        h = cfg.mlp_ratio * cfg.dim
        params[p + "mlp1/w"] = _dense_init(nxt(), cfg.dim, h)
        params[p + "mlp1/b"] = jnp.zeros((h,), jnp.float32)
        params[p + "mlp2/w"] = _dense_init(nxt(), h, cfg.dim)
        params[p + "mlp2/b"] = jnp.zeros((cfg.dim,), jnp.float32)
    params["ln_f/g"] = jnp.ones((cfg.dim,), jnp.float32)
    params["ln_f/b"] = jnp.zeros((cfg.dim,), jnp.float32)
    params["head/w"] = _dense_init(nxt(), cfg.dim, cfg.classes)
    params["head/b"] = jnp.zeros((cfg.classes,), jnp.float32)
    return params


def init_mlp(cfg: MlpConfig, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    params = {}
    dims = (cfg.inputs,) + tuple(cfg.hidden) + (cfg.classes,)
    for i in range(len(dims) - 1):
        key, sub = jax.random.split(key)
        params[f"fc{i}/w"] = _dense_init(sub, dims[i], dims[i + 1])
        params[f"fc{i}/b"] = jnp.zeros((dims[i + 1],), jnp.float32)
    return params


# ---------------------------------------------------------------------------
# Backend dispatch: every weight matmul in the model funnels through here
# ---------------------------------------------------------------------------


def _quantize_int8_np(w):
    """NumPy twin of ref.quantize_int8(axis=0). Weight preparation must run
    on *concrete* arrays even while the model is being traced (weights are
    closure constants; jnp ops on them would be staged and ConcretizationT.
    errors would fire on the float() calls), hence NumPy."""
    import numpy as np
    w = np.asarray(w, np.float32)
    amax = np.abs(w).max(axis=0, keepdims=True)
    scale = np.where(amax > 0, amax / 127.0, 1.0).astype(np.float32)
    q = np.clip(np.round(w / scale), -127, 127).astype(np.int8)
    return q, scale


def _program_array_np(w, bits):
    """NumPy twin of kernels.crossbar.program_array (see above)."""
    import numpy as np
    w = np.asarray(w, np.float32)
    nlevels = 2 ** (bits - 1) - 1
    amax = np.abs(w).max()
    scale = np.float32(amax / nlevels if amax > 0 else 1.0)
    wq = np.clip(np.round(w / scale), -nlevels, nlevels) * scale
    return wq.astype(np.float32), scale


class Backend:
    """Maps ``x @ w`` onto one of the fabric's compute-unit types."""

    def __init__(self, kind: str, noise_seed: int = 0,
                 noise_sigma: float = ANALOG_NOISE_SIGMA):
        assert kind in ("digital", "npu_int8", "analog"), kind
        self.kind = kind
        self.noise_sigma = noise_sigma
        self._noise_key = jax.random.PRNGKey(noise_seed)
        self._layer = 0

    def matmul(self, x, w):
        """x: f32[M,K] @ w: f32[K,N] on the selected CU type. ``w`` must be
        a concrete (closure-constant) array; ``x`` may be traced."""
        import numpy as np
        self._layer += 1
        if self.kind == "digital":
            return jnp.dot(x, w, preferred_element_type=jnp.float32)
        if self.kind == "npu_int8":
            wq, ws = _quantize_int8_np(w)
            return qmatmul.qmatmul_dynamic(
                x, jnp.asarray(wq), jnp.asarray(ws.reshape(1, -1)))
        # analog crossbar: pad K to the array height, program, stream.
        m, k = x.shape
        tile_k = ANALOG_TILE_K
        pad_k = (-k) % tile_k
        xp = jnp.pad(x, ((0, 0), (0, pad_k)))
        wp = np.pad(np.asarray(w, np.float32), ((0, pad_k), (0, 0)))
        wq, _ = _program_array_np(wp, ANALOG_W_BITS)
        # ADC full-scale calibration: random-sign activations give partial
        # sums ~ x_rms * w_rms * sqrt(tile_k); ANALOG_X_ABSMAX acts as the
        # sigma multiplier. Out-of-range reads clip (ADC saturates), which
        # the crossbar_ref oracle models identically.
        w_rms = float(np.sqrt(np.mean(wq ** 2)) or 1e-12)
        fullscale = max(ANALOG_X_ABSMAX * w_rms * float(np.sqrt(tile_k)), 1e-12)
        lsb = fullscale / float(2 ** (ANALOG_ADC_BITS - 1))
        nt = (k + pad_k) // tile_k
        n = w.shape[1]
        if self.noise_sigma > 0.0:
            noise_key = jax.random.fold_in(self._noise_key, self._layer)
            noise = crossbar.make_noise(
                noise_key, (nt, m, n), self.noise_sigma * lsb)
        else:
            noise = jnp.zeros((nt, m, n), jnp.float32)
        return crossbar.crossbar_mvm(
            xp, jnp.asarray(wq), noise, jnp.full((1, 1), lsb, jnp.float32),
            adc_bits=ANALOG_ADC_BITS, tile_k=tile_k)


def _layernorm(x, g, b, eps=1e-6):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _gelu(x):
    return jax.nn.gelu(x, approximate=True)


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def vit_forward(params, x, cfg: ViTConfig, backend: Backend):
    """x: f32[B, img, img, chans] -> logits f32[B, classes]."""
    b = x.shape[0]
    p, t, d = cfg.patch, cfg.tokens, cfg.dim
    g = cfg.img // p
    # Patchify: (B, g, p, g, p, C) -> (B, T, p*p*C)
    x = x.reshape(b, g, p, g, p, cfg.in_chans)
    x = x.transpose(0, 1, 3, 2, 4, 5).reshape(b, t, cfg.patch_dim)
    # Embed
    x2 = x.reshape(b * t, cfg.patch_dim)
    h = backend.matmul(x2, params["embed/w"]) + params["embed/b"]
    h = h.reshape(b, t, d) + params["pos"]
    for i in range(cfg.depth):
        pfx = f"block{i}/"
        # --- attention ---
        z = _layernorm(h, params[pfx + "ln1/g"], params[pfx + "ln1/b"])
        qkv = backend.matmul(z.reshape(b * t, d), params[pfx + "qkv/w"])
        qkv = (qkv + params[pfx + "qkv/b"]).reshape(b, t, 3, cfg.heads,
                                                    d // cfg.heads)
        q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
        q = q.transpose(0, 2, 1, 3)  # (B, H, T, dh)
        k = k.transpose(0, 2, 1, 3)
        v = v.transpose(0, 2, 1, 3)
        att = jnp.einsum("bhtd,bhsd->bhts", q, k) / (d // cfg.heads) ** 0.5
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhts,bhsd->bhtd", att, v)
        o = o.transpose(0, 2, 1, 3).reshape(b * t, d)
        o = backend.matmul(o, params[pfx + "proj/w"]) + params[pfx + "proj/b"]
        h = h + o.reshape(b, t, d)
        # --- MLP ---
        z = _layernorm(h, params[pfx + "ln2/g"], params[pfx + "ln2/b"])
        z2 = backend.matmul(z.reshape(b * t, d), params[pfx + "mlp1/w"])
        z2 = _gelu(z2 + params[pfx + "mlp1/b"])
        z2 = backend.matmul(z2, params[pfx + "mlp2/w"]) + params[pfx + "mlp2/b"]
        h = h + z2.reshape(b, t, d)
    h = _layernorm(h, params["ln_f/g"], params["ln_f/b"])
    pooled = jnp.mean(h, axis=1)
    return backend.matmul(pooled, params["head/w"]) + params["head/b"]


def mlp_forward(params, x, cfg: MlpConfig, backend: Backend):
    """x: f32[B, inputs] -> logits f32[B, classes]."""
    h = x
    n_layers = len(cfg.hidden) + 1
    for i in range(n_layers):
        h = backend.matmul(h, params[f"fc{i}/w"]) + params[f"fc{i}/b"]
        if i < n_layers - 1:
            h = jax.nn.relu(h)
    return h


# ---------------------------------------------------------------------------
# Jit-able entry points (weights closed over => baked into the HLO)
# ---------------------------------------------------------------------------


def make_vit_fn(kind: str, cfg: ViTConfig = ViTConfig(), seed: int = 0,
                noise_sigma: float = ANALOG_NOISE_SIGMA):
    params = init_vit(cfg, seed)

    def fn(x):
        return (vit_forward(params, x, cfg, Backend(kind, noise_sigma=noise_sigma)),)

    return fn


def make_mlp_fn(kind: str, cfg: MlpConfig = MlpConfig(), seed: int = 0):
    params = init_mlp(cfg, seed)

    def fn(x):
        return (mlp_forward(params, x, cfg, Backend(kind)),)

    return fn
