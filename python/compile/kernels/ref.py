"""Pure-jnp reference oracles for the ARCHYTAS Pallas kernels.

Every kernel in this package has a bit-compatible oracle here. The pytest
suite (and the hypothesis sweeps) assert ``assert_allclose(kernel, ref)``;
this file is therefore the single source of truth for the kernels'
semantics, including the analog-device artefacts (weight-level
quantization, per-tile ADC read-out, additive read noise) that model the
NVM-crossbar / photonic accelerators of the ARCHYTAS paper (Sec. II, V.B).

Nothing in this file uses Pallas; it is plain jax.numpy so it runs on any
backend and stays trivially auditable.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Quantization helpers (shared by kernels, model and tests)
# ---------------------------------------------------------------------------


def quantize_int8(x, axis=None):
    """Symmetric INT8 quantization.

    Returns ``(q, scale)`` with ``q`` int8 and ``x ~= q * scale``. ``axis``
    selects per-axis (e.g. per-output-channel) scales; ``None`` gives one
    global scale. Zero tensors get scale 1 to avoid division by zero.
    """
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def quantize_levels(w, bits: int):
    """Quantize weights onto the discrete conductance levels of an analog
    array (NVM crossbar or photonic attenuator mesh).

    A ``bits``-bit device stores ``2**(bits-1) - 1`` positive levels (sign
    is realised by differential device pairs). Returns the *dequantized*
    float weights (what the analog array actually realises) plus the level
    scale.
    """
    nlevels = 2 ** (bits - 1) - 1
    amax = jnp.max(jnp.abs(w))
    scale = jnp.where(amax > 0, amax / nlevels, 1.0)
    wq = jnp.clip(jnp.round(w / scale), -nlevels, nlevels) * scale
    return wq.astype(jnp.float32), scale.astype(jnp.float32)


def adc_quantize(v, lsb, bits: int):
    """Model an ADC read-out: round to ``lsb`` steps and clip to the
    ``bits``-bit two's-complement code range."""
    lo = float(-(2 ** (bits - 1)))
    hi = float(2 ** (bits - 1) - 1)
    return jnp.clip(jnp.round(v / lsb), lo, hi) * lsb


# ---------------------------------------------------------------------------
# Kernel oracles
# ---------------------------------------------------------------------------


def qmatmul_ref(x_q, w_q, x_scale, w_scale):
    """INT8 matmul with exact integer accumulation and float dequantization.

    x_q: int8[M,K], w_q: int8[K,N], x_scale: f32[1,1], w_scale: f32[1,N]
    (per-output-channel). Matches kernels.qmatmul: the integer accumulation
    is exact, so only the final float multiply rounds.
    """
    acc = jnp.dot(
        x_q.astype(jnp.int32), w_q.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
    return acc.astype(jnp.float32) * x_scale * w_scale


def crossbar_ref(x, wq, *, adc_bits, adc_lsb, tile_k, noise=None):
    """Analog crossbar / photonic MVM oracle.

    ``wq`` is the already level-quantized weight matrix (see
    :func:`quantize_levels`) -- what the analog array physically realises.
    The K dimension is processed in ``tile_k``-row array tiles (one
    crossbar programming each); every tile's analog partial sum is
    perturbed by ``noise[t]`` (shot/thermal/read noise, pre-drawn by the
    caller for determinism) and digitized by an ``adc_bits`` ADC with step
    ``adc_lsb`` before the digital accumulator adds it up.

    x: f32[M,K], wq: f32[K,N], noise: f32[K//tile_k, M, N] or None.
    """
    m, k = x.shape
    _, n = wq.shape
    assert k % tile_k == 0, "K must be a multiple of tile_k (pad first)"
    nt = k // tile_k
    out = jnp.zeros((m, n), jnp.float32)
    for t in range(nt):
        xs = x[:, t * tile_k:(t + 1) * tile_k]
        ws = wq[t * tile_k:(t + 1) * tile_k, :]
        partial = jnp.dot(xs, ws, preferred_element_type=jnp.float32)
        if noise is not None:
            partial = partial + noise[t]
        out = out + adc_quantize(partial, adc_lsb, adc_bits)
    return out


def blocksparse_ref(x, idx, vals, *, block_k, block_n):
    """Block-ELL sparse matmul oracle.

    Each output block-column ``j`` has ``ELL`` contributing weight blocks;
    ``idx[j, e]`` names the K-block-row of slot ``e`` and ``vals[j, e]`` is
    its ``(block_k, block_n)`` dense payload. Padding slots carry
    ``idx == -1`` and must contribute nothing.

    x: f32[M, K]; idx: int32[N/bn, ELL]; vals: f32[N/bn, ELL, bk, bn].
    """
    m = x.shape[0]
    nb, ell = idx.shape
    n = nb * block_n
    out = np.zeros((m, n), np.float32)
    xn = np.asarray(x)
    idxn = np.asarray(idx)
    valsn = np.asarray(vals)
    for j in range(nb):
        for e in range(ell):
            kb = int(idxn[j, e])
            if kb < 0:
                continue
            xs = xn[:, kb * block_k:(kb + 1) * block_k]
            out[:, j * block_n:(j + 1) * block_n] += xs @ valsn[j, e]
    return jnp.asarray(out)


def dense_from_blocksparse(idx, vals, *, block_k, block_n, k):
    """Reassemble the dense weight matrix encoded by a block-ELL pattern
    (test helper; inverse of the encoder in kernels/blocksparse.py)."""
    nb, ell = idx.shape
    n = nb * block_n
    w = np.zeros((k, n), np.float32)
    idxn = np.asarray(idx)
    valsn = np.asarray(vals)
    for j in range(nb):
        for e in range(ell):
            kb = int(idxn[j, e])
            if kb < 0:
                continue
            w[kb * block_k:(kb + 1) * block_k,
              j * block_n:(j + 1) * block_n] = valsn[j, e]
    return jnp.asarray(w)
