"""INT8 quantized matmul Pallas kernel (the digital-NPU path).

Models the integer MAC datapath of the digital NPU tiles in the ARCHYTAS
Scalable Compute Fabric (paper Sec. III) and the "dynamic quantization"
compiler technique of Sec. V.B: activations are quantized per-tensor,
weights per-output-channel, the MAC array accumulates exactly in int32,
and a single float rescale produces the output.

TPU mapping (DESIGN.md §4): the (BM, BN) output tile is MXU-shaped; the
grid's K axis streams (BM, BK)/(BK, BN) operand tiles through VMEM the way
a PIM bank streams row-buffer-resident operands. ``interpret=True`` is
mandatory on this image (CPU PJRT cannot run Mosaic custom-calls).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Default tile sizes: MXU-native 128 lanes; K tile sized so the working set
# (BM*BK + BK*BN int8 + BM*BN f32) stays well under 16 MiB of VMEM.
BM, BN, BK = 128, 128, 128


def _kernel(x_ref, w_ref, xs_ref, ws_ref, o_ref, *, nk: int):
    """Grid = (M/BM, N/BN, K/BK), K innermost (sequential on TPU)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Exact integer MAC. The f32 accumulator is exact for int8 products up
    # to |acc| < 2^24; with K <= 1024, |acc| <= 127*127*1024 < 2^24. The
    # guard lives in qmatmul() below.
    prod = jnp.dot(
        x_ref[...].astype(jnp.int32), w_ref[...].astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
    o_ref[...] += prod.astype(jnp.float32)

    @pl.when(k == nk - 1)
    def _rescale():
        o_ref[...] *= xs_ref[...] * ws_ref[...]


def _pad_to(a, mult, axis, value=0):
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def qmatmul(x_q, w_q, x_scale, w_scale, *, bm=BM, bn=BN, bk=BK):
    """out[M,N] = dequant(int8 x_q[M,K] @ int8 w_q[K,N]).

    ``x_scale`` is f32[1,1] (per-tensor), ``w_scale`` f32[1,N] (per output
    channel). Shapes need not be tile-aligned; inputs are zero-padded and
    the result is sliced back (zero padding contributes exact zeros to the
    integer accumulation).
    """
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2, (x_q.shape, w_q.shape)
    assert k <= 1024, "f32 accumulator exactness bound (see kernel doc)"
    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, k)
    xp = _pad_to(_pad_to(x_q, bm_, 0), bk_, 1)
    wp = _pad_to(_pad_to(w_q, bk_, 0), bn_, 1)
    wsp = _pad_to(w_scale, bn_, 1, value=1.0)
    mp, kp = xp.shape
    _, np_ = wp.shape
    nk = kp // bk_
    grid = (mp // bm_, np_ // bn_, nk)
    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((1, bn_), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp, x_scale, wsp)
    return out[:m, :n]


def qmatmul_dynamic(x, w_q, w_scale, *, bm=BM, bn=BN, bk=BK):
    """Dynamic-quantization entry point: float activations are quantized
    on the fly (per-tensor symmetric), then dispatched to the int8 kernel.
    This is the op the L2 model's ``npu_int8`` backend lowers to."""
    x_q, x_scale = ref.quantize_int8(x)
    return qmatmul(x_q, w_q, x_scale.reshape(1, 1), w_scale,
                   bm=bm, bn=bn, bk=bk)


def vmem_bytes(bm=BM, bn=BN, bk=BK):
    """Analytic VMEM working-set estimate for one grid step (DESIGN.md §7):
    int8 x-tile + int8 w-tile + f32 accumulator + scales."""
    return bm * bk + bk * bn + 4 * bm * bn + 4 * (1 + bn)


def mxu_utilization(m, n, k, bm=BM, bn=BN, bk=BK):
    """Fraction of MXU lanes doing useful work given padding: useful MACs /
    MACs issued over the padded grid."""
    import math
    mp = math.ceil(m / bm) * bm
    np_ = math.ceil(n / bn) * bn
    kp = math.ceil(k / bk) * bk
    return (m * n * k) / float(mp * np_ * kp)
