"""Analog crossbar MVM Pallas kernel (NVM-PIM / photonic accelerator path).

Functional model of the analog matrix-vector-multiply engines in the
ARCHYTAS paper (Sec. II "Processing-In-Memory" with NVM, and the photonic
"Processing-On-the-Flight" accelerator): weights live on a fixed-size
analog array as discrete conductance (or attenuation) levels, activations
are streamed through, and each array read-out passes through an ADC before
digital accumulation. The three analog artefacts modelled:

  1. weight quantization onto ``2**(w_bits-1)-1`` levels (done host-side
     by ``program_array``; differential pairs give the sign),
  2. additive Gaussian read noise per array read (shot/thermal noise;
     pre-drawn by the caller so kernel and oracle are deterministic),
  3. ADC quantization of every per-tile partial sum (the dominant analog
     error + energy term; cf. ISAAC/PRIME-class designs).

TPU mapping (DESIGN.md §4): one (TILE_K, BN) weight tile == one crossbar
programming, held in VMEM; the grid's K axis sequences array reads exactly
like the "program array, stream activations" schedule of the analog
papers. WDM wavelength parallelism maps to the BN lane dimension.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Crossbar array geometry: 128x128 arrays are the common NVM prototype
# size (ISAAC, PUMA) and match the MXU tile.
TILE_K = 128
BM, BN = 128, 128


def _kernel(x_ref, w_ref, noise_ref, lsb_ref, o_ref, *, nk: int, adc_bits: int):
    """Grid = (M/BM, N/BN, K/TILE_K); one step = one analog array read."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Analog MVM on the programmed tile ...
    partial = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    # ... corrupted by read noise ...
    partial = partial + noise_ref[0]
    # ... and digitized by the column ADCs before digital accumulation.
    lsb = lsb_ref[0, 0]
    lo = float(-(2 ** (adc_bits - 1)))
    hi = float(2 ** (adc_bits - 1) - 1)
    o_ref[...] += jnp.clip(jnp.round(partial / lsb), lo, hi) * lsb


def program_array(w, w_bits: int):
    """Host-side 'array programming': quantize dense float weights onto the
    device's conductance levels. Returns (wq, level_scale)."""
    return ref.quantize_levels(w, w_bits)


@functools.partial(jax.jit, static_argnames=("adc_bits", "tile_k", "bm", "bn"))
def crossbar_mvm(x, wq, noise, adc_lsb, *, adc_bits=8,
                 tile_k=TILE_K, bm=BM, bn=BN):
    """out[M,N] = sum_t ADC( x[:,tK] @ wq[tK,:] + noise[t] ).

    x: f32[M,K]; wq: f32[K,N] level-quantized (``program_array``);
    noise: f32[K/tile_k, M, N]; adc_lsb: f32[1,1]. M, N need not be
    tile-aligned (zero padding is exact through dot+noise-free padding
    lanes is avoided by padding noise with zeros too); K must be a
    multiple of ``tile_k`` — the compiler pads weights at programming time.
    """
    m, k = x.shape
    _, n = wq.shape
    assert k % tile_k == 0, "pad K to the array height first"
    nk = k // tile_k
    bm_, bn_ = min(bm, m), min(bn, n)
    pad_m = (-m) % bm_
    pad_n = (-n) % bn_
    xp = jnp.pad(x, ((0, pad_m), (0, 0)))
    wp = jnp.pad(wq, ((0, 0), (0, pad_n)))
    noisep = jnp.pad(noise, ((0, 0), (0, pad_m), (0, pad_n)))
    mp, np_ = m + pad_m, n + pad_n
    grid = (mp // bm_, np_ // bn_, nk)
    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk, adc_bits=adc_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, tile_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tile_k, bn_), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bm_, bn_), lambda i, j, kk: (kk, i, j)),
            pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp, noisep, adc_lsb)
    return out[:m, :n]


def default_adc_lsb(wq, x_absmax=1.0, tile_k=TILE_K, adc_bits=8):
    """Full-scale-calibrated ADC step: the largest partial sum a tile_k-row
    array read can produce is ~ x_absmax * max|w| * tile_k; spread that
    over the ADC code range. Returns a python float."""
    wmax = float(jnp.max(jnp.abs(wq)))
    fullscale = max(x_absmax * wmax * tile_k, 1e-12)
    return fullscale / float(2 ** (adc_bits - 1))


def make_noise(key, shape_mnk, sigma):
    """Pre-draw the per-array-read Gaussian noise tensor.
    shape_mnk = (K/tile_k, M, N); sigma in output units."""
    return sigma * jax.random.normal(key, shape_mnk, jnp.float32)


def vmem_bytes(bm=BM, bn=BN, tile_k=TILE_K):
    """Analytic VMEM working set per grid step: f32 x-tile + weight tile +
    noise tile + accumulator (DESIGN.md §7)."""
    return 4 * (bm * tile_k + tile_k * bn + bm * bn + bm * bn + 1)
