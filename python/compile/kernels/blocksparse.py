"""Block-ELL sparse matmul Pallas kernel (structured-sparsity path).

Implements the structured (block-wise) sparsification of ARCHYTAS Sec. V.B
("Pruning and sparsification for digital and analogue devices") and the
Sec. III microarchitectural support for "tensor sparsification to maximize
the utilization of compute units": the weight matrix is stored block-
compressed so a sparse-capable CU only fetches and multiplies surviving
blocks — data movement scales with density, which is exactly the PIM/NoC
win the paper targets.

Format (block-ELL): for each output block-column ``j`` a fixed number
``ELL`` of slots; ``idx[j,e]`` is the contributing K-block-row (or -1 for
padding) and ``vals[j,e]`` its dense (bk, bn) payload. Fixed ELL keeps the
schedule static — the shape a systolic/MXU pipeline (and a crossbar
macro) needs.

TPU mapping: the kernel's inner loop issues one MXU-tile MAC per surviving
block; padding slots multiply by a zero mask instead of branching, which
is how a TPU (no divergent control flow) realises "skipping".
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _kernel(x_ref, idx_ref, vals_ref, o_ref, *, ell: int, block_k: int):
    """Grid = (M/BM, N/bn). x block = full K row-panel; vals block = this
    output column's ELL payloads."""
    acc = jnp.zeros_like(o_ref)
    for e in range(ell):  # static unroll: ELL is a compile-time constant
        kb = idx_ref[0, e]
        valid = kb >= 0
        safe_kb = jnp.where(valid, kb, 0)
        xs = pl.load(x_ref, (slice(None), pl.ds(safe_kb * block_k, block_k)))
        prod = jnp.dot(xs, vals_ref[0, e],
                       preferred_element_type=jnp.float32)
        acc += jnp.where(valid, prod, 0.0)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block_k", "block_n", "bm"))
def blocksparse_matmul(x, idx, vals, *, block_k=32, block_n=32, bm=128):
    """out[M, NB*bn] = x[M,K] @ W where W is block-ELL encoded.

    x: f32[M,K] (K multiple of block_k); idx: int32[NB, ELL];
    vals: f32[NB, ELL, block_k, block_n].
    """
    m, k = x.shape
    nb, ell = idx.shape
    assert k % block_k == 0
    assert vals.shape == (nb, ell, block_k, block_n), vals.shape
    bm_ = min(bm, m)
    pad_m = (-m) % bm_
    xp = jnp.pad(x, ((0, pad_m), (0, 0)))
    mp = m + pad_m
    n = nb * block_n
    grid = (mp // bm_, nb)
    out = pl.pallas_call(
        functools.partial(_kernel, ell=ell, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, k), lambda i, j: (i, 0)),
            pl.BlockSpec((1, ell), lambda i, j: (j, 0)),
            pl.BlockSpec((1, ell, block_k, block_n), lambda i, j: (j, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bm_, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, n), jnp.float32),
        interpret=True,
    )(xp, idx, vals)
    return out[:m]


def encode_blocksparse(w, *, block_k=32, block_n=32, keep_density=None,
                       threshold=None):
    """Encode a dense K x N weight matrix into block-ELL form.

    Blocks are ranked by Frobenius norm per output block-column; either the
    top ``keep_density`` fraction (rounded up, >= 1) or all blocks above
    ``threshold`` survive. ELL = max surviving blocks over columns (>= 1).
    Returns (idx int32[NB, ELL], vals f32[NB, ELL, bk, bn]).
    """
    w = np.asarray(w, np.float32)
    k, n = w.shape
    assert k % block_k == 0 and n % block_n == 0, (w.shape, block_k, block_n)
    kb, nb = k // block_k, n // block_n
    # norms[j][kb] per output block-column
    blocks = w.reshape(kb, block_k, nb, block_n).transpose(2, 0, 1, 3)
    norms = np.sqrt((blocks ** 2).sum(axis=(2, 3)))  # (nb, kb)
    keep_lists = []
    for j in range(nb):
        order = np.argsort(-norms[j], kind="stable")
        if keep_density is not None:
            cnt = max(1, int(np.ceil(keep_density * kb)))
            keep = sorted(order[:cnt].tolist())
        else:
            thr = 0.0 if threshold is None else threshold
            keep = sorted([int(i) for i in range(kb) if norms[j, i] > thr])
        keep_lists.append(keep)
    ell = max(1, max(len(kl) for kl in keep_lists))
    idx = np.full((nb, ell), -1, np.int32)
    vals = np.zeros((nb, ell, block_k, block_n), np.float32)
    for j, kl in enumerate(keep_lists):
        for e, kbi in enumerate(kl):
            idx[j, e] = kbi
            vals[j, e] = blocks[j, kbi]
    return jnp.asarray(idx), jnp.asarray(vals)


def density(idx):
    """Fraction of non-padding slots (actual stored-block density)."""
    idxn = np.asarray(idx)
    return float((idxn >= 0).sum()) / idxn.size
