"""AOT compilation: lower every L2/L1 entry point to HLO text artifacts.

This is the *only* place Python touches the deployment flow. `make
artifacts` runs it once; afterwards the Rust coordinator is self-contained:
it loads ``artifacts/*.hlo.txt`` through PJRT (rust/src/runtime/) and never
imports Python.

Interchange is HLO **text**, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the image's xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

For every artifact we also emit golden input/output binaries (raw
little-endian f32) so the Rust test-suite can assert bit-compatible
numerics without a Python runtime, plus a mini-TOML manifest the Rust
artifact registry parses.
"""

from __future__ import annotations

import argparse
import os
import re
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import blocksparse, crossbar, qmatmul, ref


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange).

    ``as_hlo_text(True)`` = print_large_constants: the default printer
    elides big literals as ``constant({...})``, which the downstream text
    parser silently reads as *zeros* — every baked weight would be lost.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(True)


def _fmt_shape(arr) -> str:
    dt = {"float32": "f32", "int32": "s32", "int8": "s8"}[str(arr.dtype)]
    return f"{dt}[{','.join(str(d) for d in arr.shape)}]"


def hlo_op_census(text: str) -> dict:
    """Count HLO opcodes — the L2 perf gate (DESIGN.md §7) checks that each
    model variant contains exactly the expected number of dots (no
    recompute duplication)."""
    census: dict = {}
    for mm in re.finditer(r"=\s+[a-z0-9]+\[[^\]]*\][^\s]*\s+([a-z-]+)\(", text):
        op = mm.group(1)
        census[op] = census.get(op, 0) + 1
    return census


# ---------------------------------------------------------------------------
# Artifact definitions
# ---------------------------------------------------------------------------


def _rng(seed):
    return np.random.default_rng(seed)


def artifact_table():
    """name -> (fn, [example_input_arrays]). Weights are closed over and
    baked as HLO constants; runtime inputs are f32 only (the xla-crate
    Literal helpers on the Rust side are f32-oriented)."""
    arts = {}

    # --- plain GEMMs: the runtime's generic functional units -------------
    for size in (64, 128, 256):
        def gemm(x, w):
            return (jnp.dot(x, w, preferred_element_type=jnp.float32),)

        a = _rng(10 + size).standard_normal((size, size), np.float32)
        b = _rng(11 + size).standard_normal((size, size), np.float32)
        arts[f"gemm_{size}"] = (gemm, [a, b])

    # --- L1 kernel artifacts (fixed shapes, baked weights) ---------------
    wk = _rng(42).standard_normal((256, 128), np.float32)

    wq_i8, ws = ref.quantize_int8(jnp.asarray(wk), axis=0)
    ws_row = np.asarray(ws).reshape(1, -1)

    def qmm(x):
        return (qmatmul.qmatmul_dynamic(x, wq_i8, jnp.asarray(ws_row)),)

    arts["kernel_qmatmul"] = (
        qmm, [_rng(1).standard_normal((128, 256), np.float32)])

    wq_an, _ = crossbar.program_array(jnp.asarray(wk), model.ANALOG_W_BITS)
    lsb = crossbar.default_adc_lsb(
        wq_an, model.ANALOG_X_ABSMAX, model.ANALOG_TILE_K,
        model.ANALOG_ADC_BITS)
    nt = 256 // model.ANALOG_TILE_K

    def xbar(x, noise):
        return (crossbar.crossbar_mvm(
            x, wq_an, noise, jnp.full((1, 1), lsb, jnp.float32),
            adc_bits=model.ANALOG_ADC_BITS, tile_k=model.ANALOG_TILE_K),)

    arts["kernel_crossbar"] = (
        xbar,
        [_rng(2).standard_normal((128, 256), np.float32),
         np.zeros((nt, 128, 128), np.float32)])

    wsp = _rng(43).standard_normal((256, 128), np.float32)
    # Make half the K-blocks per column tiny so 50% block-density is real.
    wsp[::2, :] *= 1e-3
    idx, vals = blocksparse.encode_blocksparse(
        wsp, block_k=32, block_n=32, keep_density=0.5)

    def bsp(x):
        return (blocksparse.blocksparse_matmul(
            x, idx, vals, block_k=32, block_n=32),)

    arts["kernel_blocksparse"] = (
        bsp, [_rng(3).standard_normal((128, 256), np.float32)])

    # --- L2 model artifacts ----------------------------------------------
    vit_cfg = model.ViTConfig()
    x_img = _rng(4).standard_normal((4, 16, 16, 3), np.float32)
    for kind in ("digital", "npu_int8", "analog"):
        arts[f"vit_{kind}"] = (model.make_vit_fn(kind, vit_cfg), [x_img])

    mlp_cfg = model.MlpConfig()
    x_mlp = _rng(5).standard_normal((8, 256), np.float32)
    for kind in ("digital", "npu_int8"):
        arts[f"mlp_{kind}"] = (model.make_mlp_fn(kind, mlp_cfg), [x_mlp])

    return arts


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def build(out_dir: str, stats: bool = False, only=None) -> None:
    golden_dir = os.path.join(out_dir, "golden")
    os.makedirs(golden_dir, exist_ok=True)
    manifest_lines = [
        "# Auto-generated by python/compile/aot.py -- do not edit.", ""]
    census_report = []

    for name, (fn, inputs) in sorted(artifact_table().items()):
        if only and name not in only:
            continue
        specs = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in inputs]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        hlo_path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(hlo_path, "w") as f:
            f.write(text)

        # Golden run (same jitted computation the HLO was lowered from).
        outs = jax.jit(fn)(*[jnp.asarray(a) for a in inputs])
        in_names, out_names = [], []
        for i, a in enumerate(inputs):
            p = f"golden/{name}.in{i}.bin"
            np.asarray(a, dtype=a.dtype).tofile(os.path.join(out_dir, p))
            in_names.append(p)
        for i, o in enumerate(outs):
            p = f"golden/{name}.out{i}.bin"
            np.asarray(o).astype(np.float32).tofile(os.path.join(out_dir, p))
            out_names.append(p)

        manifest_lines += [
            "[[artifact]]",
            f'name = "{name}"',
            f'hlo = "{name}.hlo.txt"',
            "inputs = [" + ", ".join(f'"{_fmt_shape(a)}"' for a in inputs) + "]",
            "outputs = [" + ", ".join(
                f'"{_fmt_shape(np.asarray(o))}"' for o in outs) + "]",
            "golden_in = [" + ", ".join(f'"{p}"' for p in in_names) + "]",
            "golden_out = [" + ", ".join(f'"{p}"' for p in out_names) + "]",
            "",
        ]
        census = hlo_op_census(text)
        census_report.append((name, census))
        dots = census.get("dot", 0)
        print(f"  {name:24s} {len(text):>9d} chars  dot={dots}")

    with open(os.path.join(out_dir, "manifest.toml"), "w") as f:
        f.write("\n".join(manifest_lines))

    if stats:
        stats_path = os.path.join(out_dir, "hlo_stats.txt")
        with open(stats_path, "w") as f:
            for name, census in census_report:
                f.write(f"[{name}]\n")
                for op, cnt in sorted(census.items(), key=lambda kv: -kv[1]):
                    f.write(f"  {op:24s} {cnt}\n")
        print(f"wrote {stats_path}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="output directory (default: ../artifacts)")
    ap.add_argument("--stats", action="store_true",
                    help="also write an HLO opcode census")
    ap.add_argument("--only", nargs="*", default=None,
                    help="restrict to the named artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    build(args.out, stats=args.stats, only=args.only)
    print(f"artifacts written to {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
