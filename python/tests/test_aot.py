"""AOT pipeline tests: manifest consistency, golden files, HLO op census.

These run against the checked-out source (no artifacts/ needed): a small
subset is lowered into a tmpdir to validate the whole emit path.
"""

import os
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

SUBSET = ["gemm_64", "mlp_digital"]


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    aot.build(out, stats=True, only=SUBSET)
    return out


class TestEmit:
    def test_hlo_text_parses_as_hlo(self, built):
        text = open(os.path.join(built, "gemm_64.hlo.txt")).read()
        assert text.startswith("HloModule"), text[:80]
        assert "dot(" in text

    def test_manifest_lists_all(self, built):
        man = open(os.path.join(built, "manifest.toml")).read()
        for name in SUBSET:
            assert f'name = "{name}"' in man
        assert man.count("[[artifact]]") == len(SUBSET)

    def test_manifest_shapes(self, built):
        man = open(os.path.join(built, "manifest.toml")).read()
        assert 'inputs = ["f32[64,64]", "f32[64,64]"]' in man
        assert 'outputs = ["f32[8,10]"]' in man

    def test_golden_roundtrip(self, built):
        """Golden out must equal re-running the jitted fn on golden in."""
        x = np.fromfile(os.path.join(built, "golden/gemm_64.in0.bin"),
                        np.float32).reshape(64, 64)
        w = np.fromfile(os.path.join(built, "golden/gemm_64.in1.bin"),
                        np.float32).reshape(64, 64)
        want = np.fromfile(os.path.join(built, "golden/gemm_64.out0.bin"),
                           np.float32).reshape(64, 64)
        got = np.asarray(jnp.dot(jnp.asarray(x), jnp.asarray(w)))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_stats_file(self, built):
        stats = open(os.path.join(built, "hlo_stats.txt")).read()
        assert "[gemm_64]" in stats and "dot" in stats


class TestCensus:
    def test_census_counts_ops(self):
        text = ("HloModule m\n"
                "  %a = f32[2,2]{1,0} dot(x, y), lhs_contracting_dims={1}\n"
                "  %b = f32[2,2]{1,0} add(a, a)\n"
                "  %c = f32[2,2]{1,0} dot(b, y)\n")
        c = aot.hlo_op_census(text)
        assert c["dot"] == 2 and c["add"] == 1

    def test_vit_digital_dot_budget(self):
        """L2 perf gate (DESIGN.md §7): the digital ViT must lower to
        exactly the analytic dot count — 10 weight matmuls + 2 einsums per
        block — i.e. no XLA-visible recomputation."""
        cfg = model.ViTConfig()
        fn = model.make_vit_fn("digital", cfg)
        lowered = jax.jit(fn).lower(
            jax.ShapeDtypeStruct((2, 16, 16, 3), jnp.float32))
        census = aot.hlo_op_census(aot.to_hlo_text(lowered))
        expected = (1 + cfg.depth * 4 + 1) + cfg.depth * 2  # matmuls+einsums
        assert census.get("dot", 0) == expected, census

    def test_fmt_shape(self):
        assert aot._fmt_shape(np.zeros((2, 3), np.float32)) == "f32[2,3]"
        assert aot._fmt_shape(np.zeros((4,), np.int32)) == "s32[4]"
        assert aot._fmt_shape(np.zeros((1, 2), np.int8)) == "s8[1,2]"
