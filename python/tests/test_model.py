"""L2 model tests: backend variants, shapes, determinism, accuracy gaps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


def _images(seed=0, batch=2):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((batch, 16, 16, 3)).astype(np.float32))


def _mlp_in(seed=0, batch=4):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((batch, 256)).astype(np.float32))


CFG = model.ViTConfig()


class TestViT:
    def test_output_shape(self):
        x = _images()
        for kind in ("digital", "npu_int8", "analog"):
            (y,) = model.make_vit_fn(kind, CFG)(x)
            assert y.shape == (2, CFG.classes), kind

    def test_deterministic(self):
        x = _images()
        fn = model.make_vit_fn("digital", CFG)
        (a,) = fn(x)
        (b,) = fn(x)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_int8_close_to_digital(self):
        """Dynamic INT8 must track f32 within a few percent — the paper's
        Sec. V.B claim that quantization costs little accuracy."""
        x = _images()
        (yd,) = model.make_vit_fn("digital", CFG)(x)
        (yq,) = model.make_vit_fn("npu_int8", CFG)(x)
        scale = float(jnp.abs(yd).max())
        rel = float(jnp.abs(yd - yq).max()) / scale
        assert rel < 0.15, rel

    def test_analog_close_to_digital(self):
        x = _images()
        (yd,) = model.make_vit_fn("digital", CFG)(x)
        (ya,) = model.make_vit_fn("analog", CFG)(x)
        scale = float(jnp.abs(yd).max())
        rel = float(jnp.abs(yd - ya).max()) / scale
        assert rel < 0.5, rel  # analog: w-levels + ADC, coarser

    def test_analog_noise_degrades_gracefully(self):
        """More read noise -> monotonically (on average) worse agreement
        with the digital output; and zero-noise is the baked default."""
        x = _images()
        (yd,) = model.make_vit_fn("digital", CFG)(x)

        def err(sig):
            (y,) = model.make_vit_fn("analog", CFG, noise_sigma=sig)(x)
            return float(jnp.abs(y - yd).mean())

        e0, e2 = err(0.0), err(2.0)
        assert e0 < e2, (e0, e2)

    def test_same_seed_same_params(self):
        p1 = model.init_vit(CFG, seed=7)
        p2 = model.init_vit(CFG, seed=7)
        for k in p1:
            np.testing.assert_array_equal(np.asarray(p1[k]), np.asarray(p2[k]))

    def test_different_seed_different_params(self):
        p1 = model.init_vit(CFG, seed=0)
        p2 = model.init_vit(CFG, seed=1)
        assert float(jnp.abs(p1["embed/w"] - p2["embed/w"]).max()) > 0

    def test_param_inventory(self):
        p = model.init_vit(CFG)
        # embed(2) + pos + blocks * (2 ln + qkv w/b + proj w/b + 2 ln +
        # mlp1 w/b + mlp2 w/b = 12) + ln_f(2) + head(2)
        assert len(p) == 2 + 1 + CFG.depth * 12 + 2 + 2

    def test_batch_independence(self):
        """Per-sample outputs must not depend on batchmates (pure fwd)."""
        x = _images(batch=4)
        fn = model.make_vit_fn("digital", CFG)
        (full,) = fn(x)
        # Use the same batch size with sample 0 repeated so shapes (and the
        # lowered HLO) are identical, only batchmates differ.
        x_rep = jnp.tile(x[0:1], (4, 1, 1, 1))
        (rep,) = fn(x_rep)
        np.testing.assert_allclose(np.asarray(full[0]), np.asarray(rep[0]),
                                   rtol=2e-4, atol=2e-5)


class TestMLP:
    def test_output_shape(self):
        x = _mlp_in()
        for kind in ("digital", "npu_int8"):
            (y,) = model.make_mlp_fn(kind)(x)
            assert y.shape == (4, 10)

    def test_int8_close_to_digital(self):
        x = _mlp_in()
        (yd,) = model.make_mlp_fn("digital")(x)
        (yq,) = model.make_mlp_fn("npu_int8")(x)
        rel = float(jnp.abs(yd - yq).max() / jnp.abs(yd).max())
        assert rel < 0.1, rel

    def test_jit_matches_eager(self):
        x = _mlp_in()
        fn = model.make_mlp_fn("digital")
        (eager,) = fn(x)
        (jitted,) = jax.jit(fn)(x)
        np.testing.assert_allclose(np.asarray(eager), np.asarray(jitted),
                                   rtol=1e-5, atol=1e-6)


class TestBackendPrep:
    def test_np_int8_matches_jnp(self):
        from compile.kernels import ref
        w = jnp.asarray(np.random.default_rng(0).standard_normal(
            (32, 16)).astype(np.float32))
        qn, sn = model._quantize_int8_np(w)
        qj, sj = ref.quantize_int8(w, axis=0)
        np.testing.assert_array_equal(qn, np.asarray(qj))
        np.testing.assert_allclose(sn, np.asarray(sj).reshape(1, -1), rtol=1e-7)

    def test_np_levels_matches_jnp(self):
        from compile.kernels import ref
        w = jnp.asarray(np.random.default_rng(1).standard_normal(
            (32, 16)).astype(np.float32))
        wn, _ = model._program_array_np(w, 6)
        wj, _ = ref.quantize_levels(w, 6)
        np.testing.assert_allclose(wn, np.asarray(wj), rtol=1e-6, atol=1e-7)
