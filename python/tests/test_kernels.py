"""Kernel-vs-oracle correctness: the core L1 signal.

Hypothesis sweeps shapes/dtypes/parameters of every Pallas kernel against
the pure-jnp oracles in kernels/ref.py, plus directed edge cases
(tile-boundary shapes, zeros, padding slots, ADC saturation).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import blocksparse, crossbar, qmatmul, ref

SET = dict(max_examples=20, deadline=None)


def _randn(seed, shape):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(
        shape).astype(np.float32))


# ---------------------------------------------------------------------------
# qmatmul
# ---------------------------------------------------------------------------


class TestQmatmul:
    @settings(**SET)
    @given(
        m=st.integers(1, 70), k=st.integers(1, 96), n=st.integers(1, 70),
        bm=st.sampled_from([16, 32]), bn=st.sampled_from([16, 32]),
        bk=st.sampled_from([16, 32]), seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, m, k, n, bm, bn, bk, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
        w = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
        xq, xs = ref.quantize_int8(x)
        wq, ws = ref.quantize_int8(w, axis=0)
        got = qmatmul.qmatmul(xq, wq, xs.reshape(1, 1), ws.reshape(1, -1),
                              bm=bm, bn=bn, bk=bk)
        want = ref.qmatmul_ref(xq, wq, xs.reshape(1, 1), ws.reshape(1, -1))
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)

    def test_tile_exact_shapes(self):
        x, w = _randn(0, (128, 128)), _randn(1, (128, 128))
        xq, xs = ref.quantize_int8(x)
        wq, ws = ref.quantize_int8(w, axis=0)
        got = qmatmul.qmatmul(xq, wq, xs.reshape(1, 1), ws.reshape(1, -1))
        want = ref.qmatmul_ref(xq, wq, xs.reshape(1, 1), ws.reshape(1, -1))
        np.testing.assert_allclose(got, want, rtol=1e-6)

    def test_zero_input_gives_zero(self):
        xq = jnp.zeros((8, 32), jnp.int8)
        wq = jnp.ones((32, 8), jnp.int8)
        out = qmatmul.qmatmul(xq, wq, jnp.ones((1, 1)), jnp.ones((1, 8)),
                              bm=8, bn=8, bk=32)
        assert float(jnp.abs(out).max()) == 0.0

    def test_dynamic_quantization_error_bound(self):
        """Dynamic INT8 quantization must stay within the analytic error
        bound: |err| <= K * (sx*|w|max + sw*|x|max + sx*sw) / 2 roughly; we
        assert the practical relative bound used by the compiler."""
        x, w = _randn(2, (64, 128)), _randn(3, (128, 64))
        wq, ws = ref.quantize_int8(w, axis=0)
        got = qmatmul.qmatmul_dynamic(x, wq, ws.reshape(1, -1))
        want = jnp.dot(x, w)
        denom = float(jnp.abs(want).max())
        rel = float(jnp.abs(got - want).max()) / denom
        assert rel < 0.02, rel

    def test_accumulator_guard(self):
        with pytest.raises(AssertionError):
            qmatmul.qmatmul(jnp.zeros((4, 2048), jnp.int8),
                            jnp.zeros((2048, 4), jnp.int8),
                            jnp.ones((1, 1)), jnp.ones((1, 4)))

    def test_vmem_estimate_under_budget(self):
        assert qmatmul.vmem_bytes() < 16 * 1024 * 1024

    def test_mxu_utilization(self):
        assert qmatmul.mxu_utilization(128, 128, 128) == 1.0
        assert qmatmul.mxu_utilization(129, 128, 128) < 0.6


# ---------------------------------------------------------------------------
# crossbar
# ---------------------------------------------------------------------------


class TestCrossbar:
    @settings(**SET)
    @given(
        m=st.integers(1, 48), n=st.integers(1, 48),
        kt=st.integers(1, 4), tile_k=st.sampled_from([16, 32]),
        w_bits=st.integers(3, 8), adc_bits=st.integers(4, 10),
        sigma=st.floats(0.0, 0.5), seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, m, n, kt, tile_k, w_bits, adc_bits, sigma, seed):
        k = kt * tile_k
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
        w = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
        wq, _ = crossbar.program_array(w, w_bits)
        lsb = crossbar.default_adc_lsb(
            wq, float(jnp.abs(x).max()), tile_k, adc_bits)
        noise = jnp.asarray(
            (sigma * lsb * rng.standard_normal((kt, m, n))).astype(np.float32))
        got = crossbar.crossbar_mvm(
            x, wq, noise, jnp.full((1, 1), lsb, jnp.float32),
            adc_bits=adc_bits, tile_k=tile_k, bm=16, bn=16)
        want = ref.crossbar_ref(x, wq, adc_bits=adc_bits, adc_lsb=lsb,
                                tile_k=tile_k, noise=noise)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_high_adc_resolution_converges_to_exact(self):
        """With a fine ADC, many levels and no noise, the crossbar output
        must converge to the exact float matmul."""
        x, w = _randn(4, (16, 64)), _randn(5, (64, 16))
        wq, _ = crossbar.program_array(w, 16)
        lsb = crossbar.default_adc_lsb(wq, float(jnp.abs(x).max()), 32, 24)
        noise = jnp.zeros((2, 16, 16), jnp.float32)
        got = crossbar.crossbar_mvm(x, wq, noise,
                                    jnp.full((1, 1), lsb, jnp.float32),
                                    adc_bits=24, tile_k=32, bm=16, bn=16)
        want = jnp.dot(x, w)
        rel = float(jnp.abs(got - want).max() / jnp.abs(want).max())
        assert rel < 1e-3, rel

    def test_adc_saturation_clips(self):
        """Partial sums beyond ADC full scale must clip, not wrap."""
        x = jnp.ones((4, 32), jnp.float32) * 10.0
        w = jnp.ones((32, 4), jnp.float32)
        wq, _ = crossbar.program_array(w, 6)
        lsb = 0.01  # tiny step -> immediate saturation
        noise = jnp.zeros((1, 4, 4), jnp.float32)
        got = crossbar.crossbar_mvm(x, wq, noise,
                                    jnp.full((1, 1), lsb, jnp.float32),
                                    adc_bits=8, tile_k=32, bm=4, bn=4)
        want = ref.crossbar_ref(x, wq, adc_bits=8, adc_lsb=lsb, tile_k=32,
                                noise=noise)
        np.testing.assert_allclose(got, want, rtol=1e-6)
        assert float(jnp.abs(got).max()) <= 127 * lsb * 1 + 1e-6

    def test_quantize_levels_count(self):
        w = jnp.linspace(-1, 1, 1001)
        wq, scale = ref.quantize_levels(w, 4)
        levels = np.unique(np.asarray(wq))
        assert len(levels) <= 2 * (2 ** 3 - 1) + 1  # +/-7 levels + zero

    def test_vmem_estimate_under_budget(self):
        assert crossbar.vmem_bytes() < 16 * 1024 * 1024


# ---------------------------------------------------------------------------
# blocksparse
# ---------------------------------------------------------------------------


class TestBlocksparse:
    @settings(**SET)
    @given(
        m=st.integers(1, 40), kb=st.integers(1, 6), nb=st.integers(1, 4),
        bk=st.sampled_from([8, 16]), bn=st.sampled_from([8, 16]),
        keep=st.floats(0.2, 1.0), seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, m, kb, nb, bk, bn, keep, seed):
        rng = np.random.default_rng(seed)
        k, n = kb * bk, nb * bn
        x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
        w = rng.standard_normal((k, n)).astype(np.float32)
        idx, vals = blocksparse.encode_blocksparse(
            w, block_k=bk, block_n=bn, keep_density=keep)
        got = blocksparse.blocksparse_matmul(x, idx, vals,
                                             block_k=bk, block_n=bn, bm=16)
        want = ref.blocksparse_ref(x, idx, vals, block_k=bk, block_n=bn)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_full_density_equals_dense_matmul(self):
        x = _randn(6, (24, 64))
        w = np.asarray(_randn(7, (64, 32)))
        idx, vals = blocksparse.encode_blocksparse(
            w, block_k=16, block_n=16, keep_density=1.0)
        assert blocksparse.density(idx) == 1.0
        got = blocksparse.blocksparse_matmul(x, idx, vals,
                                             block_k=16, block_n=16)
        np.testing.assert_allclose(got, x @ w, rtol=1e-4, atol=1e-4)

    def test_padding_slots_contribute_nothing(self):
        """idx == -1 slots must be exact no-ops even with garbage vals."""
        x = _randn(8, (8, 32))
        idx = jnp.asarray(np.array([[0, -1], [1, -1]], np.int32))
        vals = np.random.default_rng(0).standard_normal(
            (2, 2, 16, 16)).astype(np.float32)
        got = blocksparse.blocksparse_matmul(
            x, idx, jnp.asarray(vals), block_k=16, block_n=16, bm=8)
        want = ref.blocksparse_ref(x, idx, jnp.asarray(vals),
                                   block_k=16, block_n=16)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_encoder_roundtrip_dense(self):
        """encode(keep=1.0) then dense reassembly must reproduce w."""
        w = np.asarray(_randn(9, (48, 32)))
        idx, vals = blocksparse.encode_blocksparse(
            w, block_k=16, block_n=16, keep_density=1.0)
        w2 = ref.dense_from_blocksparse(idx, vals, block_k=16, block_n=16,
                                        k=48)
        np.testing.assert_allclose(w2, w)

    def test_encoder_threshold_drops_small_blocks(self):
        w = np.zeros((32, 16), np.float32)
        w[:16] = 5.0  # only the first K-block is significant
        idx, vals = blocksparse.encode_blocksparse(
            w, block_k=16, block_n=16, threshold=1.0)
        assert idx.shape == (1, 1) and int(idx[0, 0]) == 0

    def test_energy_proxy_scales_with_density(self):
        """The stored-block count (what the fabric's sparse CU fetches)
        must scale ~linearly with keep_density."""
        w = np.asarray(_randn(10, (128, 64)))
        d25 = blocksparse.encode_blocksparse(
            w, block_k=16, block_n=16, keep_density=0.25)[0]
        d100 = blocksparse.encode_blocksparse(
            w, block_k=16, block_n=16, keep_density=1.0)[0]
        stored25 = int((np.asarray(d25) >= 0).sum())
        stored100 = int((np.asarray(d100) >= 0).sum())
        assert stored25 * 4 == stored100
