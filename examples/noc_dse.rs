//! E4 — NoC topology design-space exploration demo (paper Sec. III).
//!
//! Runs the four exploration methods over the same candidate space and
//! shows (a) they agree on the analytic optimum, (b) what the
//! simulation-in-the-loop refinement adds, (c) the Pareto front the
//! toolchain reports for cost/performance trade-offs.
//!
//! Run: `cargo run --release --example noc_dse`

use std::time::Instant;

use archytas::dse::{explore, ExploreConfig, ExploreMethod};
use archytas::Result;

fn main() -> Result<()> {
    for nodes in [16usize, 32, 64] {
        let cfg = ExploreConfig { min_nodes: nodes, max_area: 40.0, ..Default::default() };
        println!("== DSE for >= {nodes} compute nodes ==");
        for (name, method) in [
            ("exhaustive", ExploreMethod::Exhaustive),
            ("milp", ExploreMethod::Milp),
            ("smt", ExploreMethod::Smt),
            ("iterative-sim", ExploreMethod::IterativeSim),
        ] {
            let t0 = Instant::now();
            let r = explore(&cfg, method)?;
            let best = &r.candidates[r.best];
            println!(
                "  {name:<14} -> {:<12} est-lat {:>7.1}{}  area {:>6.1} mm²  [{} solver evals, {} sims, {:.1} ms]",
                best.name,
                best.est_latency,
                best.sim_latency
                    .map_or(String::new(), |l| format!(" (sim {l:.1})")),
                best.area,
                r.solver_evals,
                r.sim_evals,
                t0.elapsed().as_secs_f64() * 1e3,
            );
        }
        let r = explore(&cfg, ExploreMethod::Exhaustive)?;
        println!("  pareto front (est-lat, area, pJ/KiB):");
        for &i in &r.front {
            let c = &r.candidates[i];
            println!(
                "    {:<12} {:>8.1} {:>8.1} {:>8.0}",
                c.name, c.est_latency, c.area, c.energy_per_kib
            );
        }
        println!();
    }
    Ok(())
}
