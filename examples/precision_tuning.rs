//! E6 — TAFFO-style precision tuning demo (paper Sec. V.C, Fig. 2).
//!
//! Sweeps the output-error budget and reports, per workload, how far the
//! tuner narrows the graph, the *measured* error of the fixed-point
//! simulation, and the estimated speedup / energy ratio on the NPU model.
//!
//! Run: `cargo run --release --example precision_tuning`

use archytas::compiler::precision::{analyze_ranges, tune, Interval, TunerConfig};
use archytas::ir::interp::Mat;
use archytas::{workloads, Result};

fn main() -> Result<()> {
    let models: Vec<(&str, archytas::ir::Graph)> = vec![
        ("mlp-256", workloads::mlp(8, 256, &[128, 64], 10, 0)?),
        ("vit-tiny", workloads::vit(&workloads::VitParams::default(), 0)?),
    ];
    for (name, g) in models {
        let shape = g.nodes[0].shape;
        let mut rng = archytas::sim::Rng::new(42);
        let calib = Mat::new(
            shape,
            (0..shape[0] * shape[1]).map(|_| rng.range_f64(-2.0, 2.0) as f32).collect(),
        )
        .unwrap();
        // Show the range analysis first (the hint-driven VRA stage).
        let ranges = analyze_ranges(&g, &[Interval::new(-4.0, 4.0)])?;
        let widest = ranges
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.max_abs().partial_cmp(&b.1.max_abs()).unwrap())
            .unwrap();
        println!("== {name}: {} nodes, widest range at node {} ({}) = [{:.1}, {:.1}] ==",
            g.len(), widest.0, g.nodes[widest.0].name, widest.1.lo, widest.1.hi);
        println!(
            "  {:>8} {:>10} {:>10} {:>9} {:>10} {:>8}",
            "budget", "narrowed", "meas-err", "speedup", "energy", "<=8bit"
        );
        for budget in [0.001f32, 0.01, 0.05, 0.2] {
            let cfg = TunerConfig {
                input_hints: vec![Interval::new(-4.0, 4.0)],
                error_budget: budget,
                words: vec![8, 16, 32],
            };
            let rep = tune(&g, &calib, &cfg)?;
            let narrow8 = rep
                .formats
                .iter()
                .flatten()
                .filter(|f| f.word_bits() <= 8)
                .count();
            println!(
                "  {:>8.3} {:>10} {:>10.4} {:>8.2}x {:>9.2}x {:>8}",
                budget,
                rep.narrowed,
                rep.measured_rel_err,
                rep.est_speedup,
                rep.est_energy_ratio,
                narrow8,
            );
            assert!(rep.measured_rel_err <= budget + 1e-6);
        }
        println!();
    }
    println!("E6 precision tuning: OK (all budgets honoured)");
    Ok(())
}
