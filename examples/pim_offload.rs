//! E3 — Processing-In-Memory offload study (paper Sec. IV).
//!
//! GEMV with DRAM-resident weights, two ways:
//! * **fetch-to-core**: stream the whole weight matrix over the DRAM bus
//!   and MAC on a core (the Von Neumann baseline of paper Sec. II);
//! * **PIM**: issue bank-level MAC commands, moving only the result.
//!
//! Sweeps the footprint and prints the energy/latency ratios — the
//! "bring the computation to the data" claim, quantified on the
//! JEDEC-timing DRAM model.
//!
//! Run: `cargo run --release --example pim_offload`

use archytas::dram::{DramKind, DramSim, DramTiming, PimCommand, Request};
use archytas::Result;

fn run_pair(kind: DramKind, mb: usize) -> Result<(f64, f64, f64, f64)> {
    let t = DramTiming::new(kind);
    let bytes = mb * 1024 * 1024;
    // fetch-to-core: stream all weights
    let mut fetch = DramSim::new(t);
    for i in 0..(bytes / t.row_bytes) {
        fetch.enqueue(Request::read((i * t.row_bytes) as u64, t.row_bytes));
    }
    let fs = fetch.run_to_drain();
    // PIM: one MAC per 4 weight bytes, spread over banks
    let mut pim = DramSim::new(t);
    let macs = (bytes / 4) as u64 / t.banks as u64;
    for b in 0..t.banks {
        pim.enqueue(Request::pim((b * t.row_bytes) as u64, PimCommand::BankMac { macs }));
    }
    let ps = pim.run_to_drain();
    Ok((
        fs.cycles as f64,
        ps.cycles as f64,
        fs.metrics.total_energy_pj(),
        ps.metrics.total_energy_pj(),
    ))
}

fn main() -> Result<()> {
    for kind in [DramKind::Ddr4_2400, DramKind::Lpddr4_3200, DramKind::Hbm2] {
        println!("== {kind:?}: GEMV weight streaming vs in-bank PIM ==");
        println!(
            "  {:>6} {:>12} {:>12} {:>8} {:>12} {:>12} {:>8}",
            "MiB", "fetch cyc", "pim cyc", "speedup", "fetch nJ", "pim nJ", "saving"
        );
        for mb in [1usize, 4, 16, 64] {
            let (fc, pc, fe, pe) = run_pair(kind, mb)?;
            println!(
                "  {:>6} {:>12.0} {:>12.0} {:>7.1}x {:>12.0} {:>12.0} {:>7.1}x",
                mb,
                fc,
                pc,
                fc / pc,
                fe / 1e3,
                pe / 1e3,
                fe / pe
            );
            assert!(pe < fe, "PIM must win on energy for memory-bound GEMV");
        }
        println!();
    }
    println!("E3 PIM offload: OK (PIM wins energy at every footprint)");
    Ok(())
}
