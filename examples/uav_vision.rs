//! E8 — the end-to-end driver (paper Sec. I use case: UAV vision).
//!
//! A drone's vision pipeline classifies synthetic 16x16 frames with the
//! ViT-tiny model. All layers of the stack compose here:
//!
//! * **function**  — frames are served through the dynamic batcher
//!   (`coordinator::serve`, worker threads + leader) and executed on the
//!   AOT-compiled PJRT artifacts (L2 JAX model + L1 Pallas kernels,
//!   lowered once by `make artifacts`); the digital / int8-NPU / analog
//!   backend variants are compared for output agreement.
//! * **timing**    — the same workload's IR graph is compiled (mapped +
//!   lowered) onto the heterogeneous edge fabric and co-simulated for
//!   latency/energy, per precision.
//!
//! Run: `cargo run --release --example uav_vision`
//! Results are recorded in EXPERIMENTS.md §E8.

use std::time::Instant;

use archytas::accel::Precision;
use archytas::compiler::lowering::lower;
use archytas::compiler::mapper::{map_graph, MapStrategy};
use archytas::config::FabricConfig;
use archytas::coordinator::serve::drive_server;
use archytas::coordinator::{cosim, BatchServer};
use archytas::fabric::Fabric;
use archytas::runtime::{Runtime, Tensor};
use archytas::{workloads, Result};

const FRAME: usize = 16 * 16 * 3;
const CLASSES: usize = 10;

fn main() -> Result<()> {
    let rt = Runtime::open_default()?;

    // ------------------------------------------------------------------
    // 1. Functional serving: batched inference over the PJRT artifacts.
    // ------------------------------------------------------------------
    println!("== UAV vision: batched serving over PJRT artifacts ==");
    let spec = rt.registry().spec("vit_digital")?;
    let batch = spec.inputs[0].dims[0]; // 4
    let mut per_variant: Vec<(String, Vec<Vec<f32>>, f64, f64)> = Vec::new();
    for variant in ["vit_digital", "vit_npu_int8", "vit_analog"] {
        let exe = rt.executable(variant)?;
        let server = BatchServer::new(FRAME, CLASSES, batch);
        let t0 = Instant::now();
        let (stats, outs) = drive_server(
            &server,
            4,  // camera threads
            24, // frames each
            |cam, idx| {
                // deterministic synthetic frame
                let mut rng = archytas::sim::Rng::new((cam * 7919 + idx) as u64);
                (0..FRAME).map(|_| rng.normal() as f32).collect()
            },
            move |input| {
                // reshape the (batch, 768) batch into the artifact's
                // (batch, 16, 16, 3) frame tensor
                let img = input.clone().reshape(vec![4, 16, 16, 3])?;
                Ok(exe.run(&[img])?.remove(0))
            },
        )?;
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "  {variant:<14} {:>4} frames  {:>3} batches (mean {:.2})  p50 {:>6.0} us  p99 {:>6.0} us  {:>7.0} fps",
            stats.requests,
            stats.batches,
            stats.mean_batch(),
            stats.p50_latency_us(),
            stats.p99_latency_us(),
            stats.throughput_rps(wall),
        );
        per_variant.push((
            variant.to_string(),
            outs,
            stats.p50_latency_us(),
            stats.throughput_rps(wall),
        ));
    }

    // Cross-variant agreement: quantized/analog backends must track the
    // f32 reference on argmax decisions (paper Sec. V.B claim).
    let argmax = |row: &[f32]| -> usize {
        row.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0
    };
    let reference: Vec<usize> = per_variant[0].1.iter().map(|r| argmax(r)).collect();
    for (name, outs, _, _) in per_variant.iter().skip(1) {
        let agree = outs
            .iter()
            .zip(&reference)
            .filter(|(r, &c)| argmax(r) == c)
            .count();
        let pct = 100.0 * agree as f64 / reference.len() as f64;
        println!("  top-1 agreement {name} vs digital: {agree}/{} ({pct:.0}%)", reference.len());
        assert!(pct >= 75.0, "{name} diverged from the f32 reference");
    }

    // Bit-exactness vs the Python golden outputs (cross-language check).
    let gold_in = rt.registry().golden_inputs("vit_digital")?;
    let gold_out = rt.registry().golden_outputs("vit_digital")?;
    let got = rt.run("vit_digital", &gold_in)?;
    let delta = got[0].max_abs_diff(&gold_out[0])?;
    println!("  golden check (rust PJRT vs python jax): max|Δ| = {delta:.2e}");
    assert!(delta < 1e-4);

    // ------------------------------------------------------------------
    // 2. Timing co-simulation on the heterogeneous edge fabric.
    // ------------------------------------------------------------------
    println!("\n== UAV vision: fabric co-simulation (ViT-tiny, batch 4) ==");
    let cfg = FabricConfig::from_toml(&std::fs::read_to_string(
        archytas::repo_root().join("configs/edge16.toml"),
    )?)?;
    let fabric = Fabric::build(cfg)?;
    let g = workloads::vit(&workloads::VitParams::default(), 0)?;
    println!(
        "  fabric {}: {} tiles, {:.1} mm²; model: {} nodes, {:.1} MMACs",
        fabric.cfg.name,
        fabric.tile_count(),
        fabric.total_area().mm2,
        g.len(),
        g.total_macs() as f64 / 1e6
    );
    println!(
        "  {:<10} {:>12} {:>10} {:>12} {:>8}",
        "precision", "cycles", "us", "energy nJ", "util %"
    );
    for (name, p) in [
        ("f32", Precision::F32),
        ("int8", Precision::Int8),
        ("analog", Precision::Analog),
    ] {
        let mapping = map_graph(&g, &fabric, MapStrategy::Greedy, p)?;
        let prog = lower(&g, &fabric, &mapping)?;
        let rep = cosim(&fabric, &prog)?;
        println!(
            "  {:<10} {:>12} {:>10.2} {:>12.1} {:>8.0}",
            name,
            rep.cycles,
            rep.cycles as f64 / (fabric.cfg.freq_ghz * 1e9) * 1e6,
            rep.metrics.total_energy_pj() / 1e3,
            rep.mean_utilization() * 100.0
        );
    }

    // Sanity tie between the halves: a PJRT forward really ran and the
    // co-sim really scheduled every layer.
    let mapping = map_graph(&g, &fabric, MapStrategy::Greedy, Precision::Int8)?;
    let prog = lower(&g, &fabric, &mapping)?;
    assert_eq!(prog.exec_steps(), (0..g.len())
        .filter(|&id| archytas::compiler::mapper::node_compute(&g, id).is_some())
        .count());
    println!("\nE8 end-to-end: OK");
    Ok(())
}

// Tensor reshape helper is on archytas::runtime::Tensor (used above).
#[allow(unused)]
fn _t(_: &Tensor) {}
