//! Quickstart: the whole stack in ~60 lines.
//!
//! 1. Execute an AOT-compiled JAX artifact through PJRT from Rust
//!    (functional path — bit-compatible with the Python reference).
//! 2. Build a heterogeneous fabric, compile an MLP onto it, and
//!    co-simulate latency/energy (timing path).
//!
//! Run: `cargo run --release --example quickstart`
//! (needs `make artifacts` once beforehand).

use archytas::accel::Precision;
use archytas::compiler::lowering::lower;
use archytas::compiler::mapper::{map_graph, MapStrategy};
use archytas::config::FabricConfig;
use archytas::coordinator::cosim;
use archytas::fabric::Fabric;
use archytas::runtime::{Runtime, Tensor};
use archytas::{workloads, Result};

fn main() -> Result<()> {
    // --- functional: run a JAX-lowered GEMM via PJRT --------------------
    let rt = Runtime::open_default()?;
    let mut rng = archytas::sim::Rng::new(7);
    let x = Tensor::random(vec![64, 64], &mut rng);
    let w = Tensor::random(vec![64, 64], &mut rng);
    let y = rt.run("gemm_64", &[x, w])?;
    println!("PJRT gemm_64: out shape {:?}, out[0][0..4] = {:?}",
        y[0].dims(), &y[0].data()[..4]);

    // And the whole ViT-tiny model, checked against its golden output.
    let inputs = rt.registry().golden_inputs("vit_digital")?;
    let want = rt.registry().golden_outputs("vit_digital")?;
    let got = rt.run("vit_digital", &inputs)?;
    println!(
        "PJRT vit_digital: max|Δ| vs python golden = {:.2e}",
        got[0].max_abs_diff(&want[0])?
    );

    // --- timing: compile + map + co-simulate an MLP on a fabric ---------
    let cfg = FabricConfig::from_toml(&std::fs::read_to_string(
        archytas::repo_root().join("configs/edge16.toml"),
    )?)?;
    let fabric = Fabric::build(cfg)?;
    let g = workloads::mlp(8, 256, &[128, 64], 10, 0)?;
    let mapping = map_graph(&g, &fabric, MapStrategy::Greedy, Precision::Int8)?;
    let prog = lower(&g, &fabric, &mapping)?;
    let rep = cosim(&fabric, &prog)?;
    println!(
        "co-sim mlp on {} ({} tiles, {:.1} mm²): {} cycles ({:.2} us), {:.1} nJ, util {:.0}%",
        fabric.cfg.name,
        fabric.tile_count(),
        fabric.total_area().mm2,
        rep.cycles,
        rep.cycles as f64 / (fabric.cfg.freq_ghz * 1e9) * 1e6,
        rep.metrics.total_energy_pj() / 1e3,
        rep.mean_utilization() * 100.0
    );
    Ok(())
}
